//! Sharded pipeline-parallel execution: the PR-5 acceptance battery.
//!
//! * logits **bit-identical** between `--shards {1,2,3}` pipeline decode and
//!   unsharded [`DecodeState`] on dense, mixed 2/3/4/8-bit packed, and
//!   int8-KV configurations — under the dispatched *and* the forced-scalar
//!   kernel tables;
//! * the step-level scheduler admits mid-flight: a late short request
//!   completes before an earlier long generation finishes;
//! * serve e2e over `--shards 2`;
//! * shutdown: dropping a (sharded) batcher joins every worker thread.

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::model::{DecodeState, ExecModel, KvSpec, ModelConfig, ModelExec, ModelWeights};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantPlan;
use tsgo::serve::{
    argmax_token, request_generation, server::serve_in_background, BatcherConfig,
    DynamicBatcher, GenRequest, ServerConfig, StepJob,
};
use tsgo::shard::{ShardPlan, ShardedModel};
use tsgo::tensor::kernels::{set_forced, ForcedKernel};
use tsgo::util::rng::Rng;

/// Serializes tests that flip the process-wide forced-kernel state (same
/// rationale as the lock in `tests/kv_cache.rs`): a concurrent flip
/// mid-decode would make a real scalar/SIMD divergence nondeterministic.
fn force_lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// A 4-layer tiny-width config so a 3-shard plan is a real split
/// (the tiny preset's 2 layers would clamp `--shards 3` down to 2).
fn cfg4() -> ModelConfig {
    ModelConfig { vocab: 256, d_model: 64, n_layers: 4, n_heads: 2, ffn: 128, seq_len: 64 }
}

fn dense4(seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    ModelWeights::init(cfg4(), &mut rng)
}

/// Mixed-precision packed model over the 4-layer config: every specialized
/// dequant width (2/3/4/8-bit) in one checkpoint, executed packed.
fn mixed_packed4() -> ExecModel {
    let w = dense4(77);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
    let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
    let plan = QuantPlan::parse_with_defaults(
        "rtn:bits=2,group=32;wv=bits3;wo=bits4;w2=bits8",
        4,
        32,
    )
    .unwrap();
    let (qm, _) = quantize_model(&w, &calib, &PipelineConfig::from_plan(plan)).unwrap();
    ExecModel::from_quantized(&qm)
}

/// Step `tokens` through an `n_shards` pipeline and assert every position's
/// logits are bit-identical to an unsharded [`DecodeState`] decode.
fn assert_pipeline_bit_identical<M: ModelExec + Send + Sync + 'static>(
    model: Arc<M>,
    n_shards: usize,
    kv: KvSpec,
    tokens: &[u8],
    label: &str,
) {
    let mut st = DecodeState::with_kv(model.as_ref(), kv);
    let sm = ShardedModel::new(model.clone(), n_shards);
    let mut dec = sm.decoder(kv);
    let slot = dec.admit().unwrap();
    for (pos, &tok) in tokens.iter().enumerate() {
        let want = st.step(tok);
        let got = dec.step(&[StepJob::single(slot, pos, tok)]);
        assert_eq!(got.len(), 1);
        let got = got[0].as_ref().expect("pipeline step failed");
        assert_eq!(got.len(), want.len(), "{label}: logit width");
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{label}: shards={n_shards} pos={pos} logit {i}: {a} vs {b}"
            );
        }
    }
    dec.retire(slot);
}

#[test]
fn pipeline_logits_bit_identical_across_shard_counts_and_configs() {
    let _guard = force_lock();
    let dense = Arc::new(dense4(11));
    let packed = Arc::new(mixed_packed4());
    let tokens: Vec<u8> = vec![3, 141, 59, 26, 53, 58, 97, 93, 23, 84];
    let kv8 = KvSpec::PackedGroupwise { bits: 8, group: 64 };
    for force in [ForcedKernel::Scalar, ForcedKernel::Best] {
        set_forced(force);
        for shards in [1usize, 2, 3] {
            assert_pipeline_bit_identical(
                dense.clone(),
                shards,
                KvSpec::DenseF32,
                &tokens,
                &format!("dense f32-KV under {force:?}"),
            );
            assert_pipeline_bit_identical(
                packed.clone(),
                shards,
                KvSpec::DenseF32,
                &tokens,
                &format!("mixed-packed f32-KV under {force:?}"),
            );
            assert_pipeline_bit_identical(
                packed.clone(),
                shards,
                kv8,
                &tokens,
                &format!("mixed-packed int8-KV under {force:?}"),
            );
        }
    }
    set_forced(ForcedKernel::Auto);
}

#[test]
fn pipeline_isolates_concurrent_sequences() {
    // Bit-exact comparison: hold the lock so the forcing test can't flip
    // the kernel table between the reference and pipeline steps.
    let _guard = force_lock();
    // Two slots stepped as one microbatched job list must track two
    // independent DecodeStates exactly — per-slot, per-shard KV isolation.
    let model = Arc::new(dense4(12));
    let sm = ShardedModel::new(model.clone(), 2);
    let mut dec = sm.decoder(KvSpec::DenseF32);
    let s0 = dec.admit().unwrap();
    let s1 = dec.admit().unwrap();
    assert_ne!(s0, s1);
    let mut ref0 = DecodeState::new(model.as_ref());
    let mut ref1 = DecodeState::new(model.as_ref());
    let seq0: Vec<u8> = vec![10, 20, 30, 40, 50, 60];
    let seq1: Vec<u8> = vec![200, 150, 100, 50, 25, 12];
    for pos in 0..seq0.len() {
        let want0 = ref0.step(seq0[pos]);
        let want1 = ref1.step(seq1[pos]);
        let got = dec.step(&[
            StepJob::single(s0, pos, seq0[pos]),
            StepJob::single(s1, pos, seq1[pos]),
        ]);
        let g0 = got[0].as_ref().unwrap();
        let g1 = got[1].as_ref().unwrap();
        assert!(g0.iter().zip(&want0).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(g1.iter().zip(&want1).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
    // retire one and admit a fresh sequence into the recycled slot: it must
    // start from an empty cache, not the retired sequence's history.
    dec.retire(s0);
    let s2 = dec.admit().unwrap();
    let mut ref2 = DecodeState::new(model.as_ref());
    let want = ref2.step(99);
    let got = dec.step(&[StepJob::single(s2, 0, 99)]);
    let fresh = got[0].as_ref().unwrap();
    assert!(fresh.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
}

#[test]
fn sharded_model_delegates_decode_perplexity() {
    // Bit-exact comparison — serialize against the kernel-forcing test.
    let _guard = force_lock();
    // ShardedModel anywhere a ModelExec goes: decode_perplexity through the
    // wrapper equals the inner model's bit for bit (same code path).
    let model = Arc::new(dense4(13));
    let sm = ShardedModel::new(model.clone(), 3);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 4_000, 6);
    let kv = KvSpec::DenseF32;
    let a = tsgo::eval::decode_perplexity(model.as_ref(), &corpus.bytes, 32, 2, kv);
    let b = tsgo::eval::decode_perplexity(&sm, &corpus.bytes, 32, 2, kv);
    assert_eq!(a.to_bits(), b.to_bits());
}

#[test]
fn plan_for_mixed_precision_balances_bytes_not_layers() {
    // The mixed checkpoint's layers have unequal footprints; the plan must
    // cover all layers contiguously and its per-shard byte spread must be
    // no worse than the layer-count split's.
    let em = mixed_packed4();
    let plan = ShardPlan::for_model(&em, 2);
    assert_eq!(plan.n_shards(), 2);
    assert_eq!(plan.n_layers(), 4);
    use tsgo::model::BlockLinears;
    let total: usize = em.layers().iter().map(|l| l.weight_bytes()).sum::<usize>()
        + em.embed_bytes()
        + em.head_bytes();
    assert_eq!(plan.weight_bytes(0) + plan.weight_bytes(1), total);
}

#[test]
fn late_short_request_completes_before_long_generation() {
    // The admission-stall fix, end to end: a long generation is mid-flight;
    // a short request arriving afterwards must join the running batch (not
    // wait for the long one) and finish first.
    let m = Arc::new(dense4(14));
    let b = Arc::new(DynamicBatcher::spawn(
        m,
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
    ));
    let (done_tx, done_rx) = channel::<(&'static str, Instant)>();
    let long = {
        let (b, tx) = (b.clone(), done_tx.clone());
        std::thread::spawn(move || {
            let r = b
                .generate(GenRequest { prompt: vec![5, 6, 7], max_new: 4000, ..Default::default() })
                .unwrap();
            tx.send(("long", Instant::now())).unwrap();
            r
        })
    };
    // let the long generation get well into decode before the short arrives
    std::thread::sleep(Duration::from_millis(10));
    let short = {
        let (b, tx) = (b.clone(), done_tx.clone());
        std::thread::spawn(move || {
            let r = b
                .generate(GenRequest { prompt: vec![9, 9], max_new: 2, ..Default::default() })
                .unwrap();
            tx.send(("short", Instant::now())).unwrap();
            r
        })
    };
    let (first, _) = done_rx.recv().unwrap();
    assert_eq!(
        first, "short",
        "short request did not overtake the in-flight long generation"
    );
    let short_resp = short.join().unwrap();
    let long_resp = long.join().unwrap();
    assert_eq!(short_resp.tokens.len(), 2);
    assert_eq!(long_resp.tokens.len(), 4000);
    // co-running proves mid-flight admission (it would be 1 under the old
    // whole-batch scheduler, which only batched requests that arrived
    // together within max_wait)
    assert!(
        short_resp.batch_size >= 2,
        "short request never shared a step with the long one \
         (batch_size {}); was it queued behind the whole generation?",
        short_resp.batch_size
    );
    // and the split metric shows it barely queued: admission happened
    // mid-flight, not after the long generation's ~4000 steps
    assert!(
        short_resp.queue_wait < long_resp.decode_time,
        "queue_wait {:?} vs long decode {:?}",
        short_resp.queue_wait,
        long_resp.decode_time
    );
}

#[test]
fn sharded_batcher_tokens_match_unsharded() {
    let _guard = force_lock();
    let m = Arc::new(mixed_packed4());
    let req = GenRequest { prompt: vec![65, 66, 67, 68], max_new: 12, ..Default::default() };
    let unsharded = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
    let a = unsharded.generate(req.clone()).unwrap();
    for shards in [2usize, 3] {
        let sharded = DynamicBatcher::spawn(
            m.clone(),
            BatcherConfig { shards, ..Default::default() },
        );
        let b = sharded.generate(req.clone()).unwrap();
        assert_eq!(a.tokens, b.tokens, "shards={shards} diverged from unsharded");
    }
}

#[test]
fn serve_e2e_with_two_shards() {
    let _guard = force_lock();
    // `tsgo serve --packed --kv-bits 8 --shards 2` in miniature: the full
    // TCP + scheduler + pipeline stack, tokens equal to a direct decode.
    let em = Arc::new(mixed_packed4());
    let kv = KvSpec::PackedGroupwise { bits: 8, group: 64 };
    let prompt = [65u8, 66, 67];
    let want = {
        let mut st = DecodeState::with_kv(em.as_ref(), kv);
        let mut logits = Vec::new();
        for &t in &prompt {
            logits = st.step(t);
        }
        let mut out = Vec::new();
        for _ in 0..6 {
            let next = argmax_token(&logits).unwrap();
            out.push(next);
            logits = st.step(next);
        }
        out
    };
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        batcher: BatcherConfig { kv, shards: 2, ..Default::default() },
        max_connections: Some(2),
        ..Default::default()
    };
    let sm = Arc::new(ShardedModel::new(em, 2));
    let (addr, handle) = serve_in_background(sm, cfg).unwrap();
    let a = request_generation(&addr.to_string(), &prompt, 6).unwrap();
    assert_eq!(a.tokens, want, "served sharded tokens diverged from direct decode");
    assert!(a.latency_ms > 0.0);
    assert!((a.queue_wait_ms + a.prefill_ms + a.decode_ms - a.latency_ms).abs() < 1e-6);
    assert!((a.queue_wait_ms + a.prefill_ms - a.ttft_ms).abs() < 1e-6);
    let b = request_generation(&addr.to_string(), &prompt, 6).unwrap();
    assert_eq!(a.tokens, b.tokens, "sharded serving must stay deterministic");
    handle.join().unwrap();
}

#[test]
fn dropping_a_sharded_batcher_joins_all_threads() {
    // Shutdown satellite: batcher Drop must close the queue, join the
    // scheduler, and (transitively) join every shard thread — repeated
    // cycles must neither hang nor error.
    let m = Arc::new(dense4(15));
    for _ in 0..4 {
        let b = DynamicBatcher::spawn(
            m.clone(),
            BatcherConfig { shards: 3, ..Default::default() },
        );
        let r = b
            .generate(GenRequest { prompt: vec![1, 2, 3], max_new: 3, ..Default::default() })
            .unwrap();
        assert_eq!(r.tokens.len(), 3);
        drop(b);
    }
}
