//! Quantized-KV-cache integration: decode with group-wise int8/int4 K/V
//! against the f32 cache on a mixed 2/3/4/8-bit packed checkpoint —
//! token identity, documented ppl tolerances, forced-scalar vs dispatched
//! bit-identity, serve-path plumbing, and amortized cache growth.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::model::store::{load_quantized_packed, save_quantized};
use tsgo::model::{DecodeState, ExecModel, KvSpec, ModelExec, ModelWeights, Preset};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantPlan;
use tsgo::serve::{
    request_generation, server::serve_in_background, BatcherConfig, ServerConfig,
};
use tsgo::tensor::kernels::{set_forced, ForcedKernel};
use tsgo::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("tsgo_kv_cache");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Serializes the tests in this binary against the one that flips the
/// process-wide forced-kernel state: without it, a set_forced(Scalar/Best)
/// mid-decode of a concurrently running test would make failures
/// nondeterministic exactly when a scalar/SIMD divergence exists (the
/// condition these tests exist to catch). Poison-tolerant so one panicking
/// test doesn't cascade.
fn force_lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// The kernel-matrix checkpoint: every specialized dequant width
/// (2/3/4/8-bit linears) through the real pipeline, loaded packed.
fn mixed_checkpoint(name: &str) -> ExecModel {
    let cfg = Preset::Tiny.config();
    let mut rng = Rng::new(4321);
    let w = ModelWeights::init(cfg, &mut rng);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
    let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
    let plan = QuantPlan::parse_with_defaults(
        "rtn:bits=2,group=32;wv=bits3;wo=bits4;w2=bits8",
        4,
        32,
    )
    .unwrap();
    let (qm, _) = quantize_model(&w, &calib, &PipelineConfig::from_plan(plan)).unwrap();
    let p = tmp(name);
    save_quantized(&p, &qm).unwrap();
    load_quantized_packed(&p).unwrap()
}

fn greedy<M: ModelExec>(m: &M, kv: KvSpec, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let mut st = DecodeState::with_kv(m, kv);
    let mut logits = Vec::new();
    for &t in prompt {
        logits = st.step(t);
    }
    let mut out = Vec::new();
    for _ in 0..max_new {
        let next = tsgo::serve::argmax_token(&logits).unwrap();
        out.push(next);
        logits = st.step(next);
    }
    out
}

#[test]
fn int8_kv_decode_token_identical_to_f32_kv_for_64_steps() {
    let _guard = force_lock();
    // The acceptance bar: greedy decode with the int8 group-wise KV cache
    // must emit the same tokens as the f32 cache for ≥64 steps on the
    // mixed-width checkpoint. A random-init checkpoint has near-uniform
    // logits (argmax margins of ~1e-2, comparable to any genuine numeric
    // perturbation), so tie the LM head to the embedding first: logits then
    // align with the hidden state's dominant embedding component and greedy
    // margins are decisive rather than coin flips — the comparison measures
    // the KV path, not tie-breaking luck.
    let mut em = mixed_checkpoint("kv_ident.tsr");
    em.head = em.embed.clone();
    let prompt = [17u8, 94, 3, 201];
    let want = greedy(&em, KvSpec::DenseF32, &prompt, 64);
    let got = greedy(&em, KvSpec::PackedGroupwise { bits: 8, group: 64 }, &prompt, 64);
    assert_eq!(got, want, "int8-KV greedy decode diverged from f32-KV");
}

#[test]
fn kv_ppl_within_documented_tolerances() {
    let _guard = force_lock();
    // ROADMAP "Quantized KV cache": int8-KV decode ppl within 2% of f32-KV,
    // int4-KV within 5%, measured end to end on the packed checkpoint.
    let em = mixed_checkpoint("kv_ppl.tsr");
    let corpus = Corpus::generate(CorpusKind::SynthC4, 12_000, 8);
    let base = tsgo::eval::decode_perplexity(&em, &corpus.bytes, 32, 2, KvSpec::DenseF32);
    for (bits, tol) in [(8u8, 0.02), (4, 0.05)] {
        let q = tsgo::eval::decode_perplexity(
            &em,
            &corpus.bytes,
            32,
            2,
            KvSpec::PackedGroupwise { bits, group: 64 },
        );
        let delta = (q / base - 1.0).abs();
        assert!(
            delta < tol,
            "int{bits}-KV ppl {q} vs f32-KV {base} (delta {delta:.4} > {tol})"
        );
    }
}

#[test]
fn kv_attend_forced_scalar_vs_dispatched_bit_identical() {
    let _guard = force_lock();
    // The dispatch invariant, end to end through the decode loop: packed
    // weights AND packed KV under the forced-scalar table must produce the
    // exact same logit bits as under the detected-best table, step by step.
    let em = mixed_checkpoint("kv_dispatch.tsr");
    let tokens: Vec<u8> = (0..32u8).map(|i| i.wrapping_mul(37)).collect();
    for kv in [
        KvSpec::PackedGroupwise { bits: 8, group: 64 },
        KvSpec::PackedGroupwise { bits: 4, group: 16 },
        KvSpec::PackedGroupwise { bits: 2, group: 8 },
    ] {
        set_forced(ForcedKernel::Scalar);
        let mut st_s = DecodeState::with_kv(&em, kv);
        let scalar_logits: Vec<Vec<f32>> = tokens.iter().map(|&t| st_s.step(t)).collect();
        set_forced(ForcedKernel::Best);
        let mut st_b = DecodeState::with_kv(&em, kv);
        let best_logits: Vec<Vec<f32>> = tokens.iter().map(|&t| st_b.step(t)).collect();
        set_forced(ForcedKernel::Auto);
        for (t, (a, b)) in scalar_logits.iter().zip(&best_logits).enumerate() {
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{} step {t} logit {i}: scalar {x} vs dispatched {y}",
                    kv.label()
                );
            }
        }
    }
}

#[test]
fn long_decode_grows_cache_amortized() {
    let _guard = force_lock();
    // The seed DecodeState rebuilt both caches every token (O(T²) copies);
    // both representations must now grow O(log T) times per cache.
    let em = mixed_checkpoint("kv_growth.tsr");
    let n_caches = 2 * em.config().n_layers; // K + V per layer
    for kv in [KvSpec::DenseF32, KvSpec::PackedGroupwise { bits: 8, group: 64 }] {
        let mut st = DecodeState::with_kv(&em, kv);
        let mut logits = st.step(1);
        for _ in 0..160 {
            let next = tsgo::serve::argmax_token(&logits).unwrap();
            logits = st.step(next);
        }
        // 161 appends per cache; doubling from a 16-row floor needs ≤ 5
        // grows (16→32→64→128→256), plus the initial allocation.
        assert!(
            st.kv_grow_events() <= 6 * n_caches,
            "{}: {} grow events across {n_caches} caches for 161 tokens",
            kv.label(),
            st.kv_grow_events()
        );
        assert!(st.kv_bytes() > 0);
    }
}

#[test]
fn serve_packed_with_quantized_kv_end_to_end() {
    let _guard = force_lock();
    // `tsgo serve --packed --kv-bits 8` in miniature: the full TCP + batcher
    // stack over the packed checkpoint with an int8 KV cache, and the served
    // tokens equal a direct decode with the same spec.
    let em = mixed_checkpoint("kv_serve.tsr");
    let kv = KvSpec::PackedGroupwise { bits: 8, group: 64 };
    let want = greedy(&em, kv, &[10, 20, 30, 40], 8);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        batcher: BatcherConfig { kv, ..Default::default() },
        max_connections: Some(1),
        ..Default::default()
    };
    let (addr, handle) = serve_in_background(Arc::new(em), cfg).unwrap();
    let resp = request_generation(&addr.to_string(), &[10, 20, 30, 40], 8).unwrap();
    assert_eq!(resp.tokens, want, "served int8-KV tokens diverged from direct decode");
    handle.join().unwrap();
}
