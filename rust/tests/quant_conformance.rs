//! Conformance suite for the unified quantization API: every registered
//! [`tsgo::quant::LayerQuantizer`] runs through one shared battery —
//! single-layer invariants (dequant shape/finiteness, ints in range,
//! pack/unpack round-trip), whole-model pipeline coverage, checkpoint
//! round-trips that preserve each linear's spec, and the mixed-precision
//! `QuantPlan` end-to-end path (quantize → save → reload → eval).

use tsgo::calib::{calibration_batches, Batch, Corpus, CorpusKind};
use tsgo::model::{store, LinearKind, ModelWeights, Preset};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::{resolve_quantizer, QuantContext, QuantPlan, QuantSpec, QUANTIZER_NAMES};
use tsgo::tensor::Matrix;
use tsgo::util::rng::Rng;

fn layer_problem(out: usize, inp: usize, seed: u64) -> (Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(out, inp, 1.0, &mut rng);
    let t = inp * 6;
    let mut x = Matrix::zeros(inp, t);
    for c in 0..t {
        let mut prev = 0.0f32;
        for r in 0..inp {
            let energy = if r % 7 == 0 { 4.0 } else { 0.5 };
            let v = 0.6 * prev + rng.normal() as f32 * energy;
            x[(r, c)] = v;
            prev = v;
        }
    }
    let mut h = x.matmul_bt(&x);
    h.scale_inplace(1.0 / t as f32);
    (w, h)
}

fn model_setup() -> (ModelWeights, Vec<Batch>) {
    let cfg = Preset::Tiny.config();
    let mut rng = Rng::new(4242);
    let w = ModelWeights::init(cfg, &mut rng);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
    let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
    (w, calib)
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("tsgo_conformance");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn every_registered_quantizer_passes_the_layer_battery() {
    let (w, h) = layer_problem(12, 64, 1);
    let ctx = QuantContext::default();
    for name in QUANTIZER_NAMES {
        let quantizer =
            resolve_quantizer(name).unwrap_or_else(|| panic!("'{name}' not registered"));
        assert_eq!(quantizer.name(), name);
        for bits in [2u8, 4] {
            let spec = QuantSpec::new(bits, 32);
            let res = quantizer
                .quantize(&w, &h, None, &spec, &ctx)
                .unwrap_or_else(|e| panic!("{name} bits={bits}: {e}"));
            // losses are finite and ordered
            assert!(res.layer_loss.is_finite() && res.layer_loss >= 0.0, "{name}");
            assert!(res.loss_before_stage2.is_finite(), "{name}");
            // dequant shape + finiteness
            let d = res.quantized.dequantize();
            assert_eq!((d.rows, d.cols), (w.rows, w.cols), "{name}");
            assert!(d.data.iter().all(|v| v.is_finite()), "{name} bits={bits}");
            // spec recorded on the artifact
            assert_eq!(res.quantized.bits, bits, "{name}");
            assert_eq!(res.quantized.group_size, 32, "{name}");
            // ints in range + pack/unpack round-trip
            let qmax = (1u32 << bits) - 1;
            for r in 0..res.quantized.rows {
                let ints = res.quantized.qweight[r].unpack();
                assert_eq!(ints.len(), w.cols, "{name} row {r}");
                assert!(
                    ints.iter().all(|&v| (v as u32) <= qmax),
                    "{name} bits={bits} row {r}: int out of range"
                );
                let repacked = tsgo::quant::PackedInts::pack(&ints, bits);
                assert_eq!(repacked, res.quantized.qweight[r], "{name} row {r}");
            }
        }
    }
}

#[test]
fn every_registered_quantizer_runs_the_pipeline_and_roundtrips() {
    let (w, calib) = model_setup();
    let tokens: Vec<u8> = (0..24).map(|i| (i * 13 % 251) as u8).collect();
    for name in QUANTIZER_NAMES {
        let cfg = PipelineConfig::new(QuantSpec::new(4, 32), name);
        let (qm, report) = quantize_model(&w, &calib, &cfg)
            .unwrap_or_else(|e| panic!("{name}: pipeline failed: {e}"));
        assert_eq!(qm.linears.len(), 7 * w.config.n_layers, "{name}");
        assert!(report.total_loss().is_finite(), "{name}");
        assert!(report.linears.iter().all(|l| l.quantizer == name), "{name}");
        assert!(
            qm.quantizers.values().all(|q| q == name),
            "{name}: provenance mismatch"
        );

        // checkpoint round-trip preserves the per-linear spec and weights
        let path = tmp(&format!("conf_{name}.tsr"));
        store::save_quantized(&path, &qm).unwrap();
        let qm2 = store::load_quantized(&path).unwrap();
        assert_eq!(qm2.quantizers, qm.quantizers, "{name}");
        for li in 0..w.config.n_layers {
            for kind in LinearKind::ALL {
                let a = &qm.linears[&(li, kind.label())];
                let b = &qm2.linears[&(li, kind.label())];
                assert_eq!((a.bits, a.group_size), (b.bits, b.group_size), "{name}");
                assert_eq!(a.perm, b.perm, "{name} perm");
                assert_eq!(a.channel_scales, b.channel_scales, "{name} channel scales");
                assert_eq!(
                    qm.weights.layers[li].linear(kind),
                    qm2.weights.layers[li].linear(kind),
                    "{name} layer {li} {}",
                    kind.label()
                );
            }
        }

        // the reloaded model runs
        let logits = tsgo::model::forward_logits(&qm2.weights, &tokens);
        assert!(
            logits.data.iter().all(|v| v.is_finite()),
            "{name}: non-finite logits after reload"
        );
    }
}

#[test]
fn mixed_precision_plan_quantizes_saves_reloads_and_evals() {
    // The acceptance scenario: two bit-widths (and three quantizers) in one
    // model, end-to-end through quantize → save → load → eval.
    let (w, calib) = model_setup();
    let plan =
        QuantPlan::parse_with_defaults("ours:bits=4,group=32;wv,wo=bits2;l0=awq", 4, 32).unwrap();
    let (qm, report) =
        quantize_model(&w, &calib, &PipelineConfig::from_plan(plan.clone())).unwrap();

    // both bit-widths actually present
    let bits: std::collections::BTreeSet<u8> = qm.linears.values().map(|q| q.bits).collect();
    assert_eq!(bits.into_iter().collect::<Vec<_>>(), vec![2, 4]);
    for ((layer, kind), q) in &qm.linears {
        let want_bits = if *kind == "wv" || *kind == "wo" { 2 } else { 4 };
        assert_eq!(q.bits, want_bits, "layer {layer} {kind}");
        let want_q = if *layer == 0 { "awq" } else { "ours" };
        assert_eq!(&qm.quantizers[&(*layer, *kind)], want_q, "layer {layer} {kind}");
    }
    // the report sees the same routing (for per-method bench columns)
    assert!(report.method_summary().len() >= 3);

    // save → reload: heterogeneous specs survive, dense weights identical
    let path = tmp("mixed.tsr");
    store::save_quantized(&path, &qm).unwrap();
    let qm2 = store::load_quantized(&path).unwrap();
    assert_eq!(qm2.quantizers, qm.quantizers);
    for ((layer, kind), q) in &qm.linears {
        let q2 = &qm2.linears[&(*layer, *kind)];
        assert_eq!((q.bits, q.group_size), (q2.bits, q2.group_size), "layer {layer} {kind}");
    }
    let tokens: Vec<u8> = (0..32).map(|i| (i * 11 % 251) as u8).collect();
    let a = tsgo::model::forward_logits(&qm.weights, &tokens);
    let b = tsgo::model::forward_logits(&qm2.weights, &tokens);
    assert!(a.max_abs_diff(&b) < 1e-6, "reload changed the model");

    // evals end-to-end on the reloaded heterogeneous checkpoint
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 2);
    let ppl = tsgo::eval::perplexity(&qm2.weights, &corpus.bytes, 32, 4);
    assert!(ppl.is_finite() && ppl > 0.0, "ppl = {ppl}");
}

#[test]
fn plan_resolution_is_visible_in_reports() {
    // A layer-targeted rule shows up in LinearReport rows exactly where the
    // plan says it should.
    let (w, calib) = model_setup();
    let plan = QuantPlan::parse_with_defaults("gptq:bits=4,group=32;l1=rtn", 4, 32).unwrap();
    let (_, report) = quantize_model(&w, &calib, &PipelineConfig::from_plan(plan)).unwrap();
    for l in &report.linears {
        let want = if l.layer == 1 { "rtn" } else { "gptq" };
        assert_eq!(l.quantizer, want, "layer {} {:?}", l.layer, l.kind);
    }
}
