//! Fault-tolerance battery: proves the PR 8 recovery paths by injecting
//! deterministic faults through `tsgo::util::fault` and asserting the blast
//! radius — a worker panic errors exactly its sequence (neighbours'
//! tokens bit-identical to solo decode, pool respawned), a shard death
//! rebuilds the whole chain and the next request succeeds, lost replies
//! never leak KV-pool pages, and the `--request-timeout`/`--step-timeout`
//! deadlines bound every wait the old code left unbounded.
//!
//! The fault plane is process-global, so every test here serializes on one
//! mutex: a plan armed for one test must never leak faults into another's
//! decode. Plans armed via `BatcherConfig::faults` are disarmed by the
//! batcher's drop; tests that arm directly disarm before releasing the lock.

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use tsgo::kvpool::{KvPool, PoolCfg};
use tsgo::model::{DecodeState, KvSpec, ModelExec, ModelWeights, Preset};
use tsgo::serve::{
    argmax_token, AdmitVerdict, BatcherConfig, DynamicBatcher, GenRequest, GenResponse,
    LocalBackend, Pending, RequestQueue, StepBackend, StepJob,
};
use tsgo::serve::scheduler_loop;
use tsgo::util::fault::{self, FaultPlan, FaultPoint};
use tsgo::util::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn serialize() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn model(seed: u64) -> Arc<ModelWeights> {
    let mut rng = Rng::new(seed);
    Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng))
}

/// Solo greedy reference decode — what every surviving sequence must match.
fn reference(m: &ModelWeights, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let mut st = DecodeState::new(m);
    let mut logits = Vec::new();
    for &t in prompt {
        logits = st.step(t);
    }
    let mut out = Vec::new();
    for _ in 0..max_new {
        let next = argmax_token(&logits).unwrap();
        out.push(next);
        logits = st.step(next);
    }
    out
}

/// The pooled-step scenarios need at least two pool workers: with one, a
/// worker death also strands the jobs queued behind it (they error on the
/// step deadline, which is correct containment but a different scenario).
fn pool_is_wide() -> bool {
    tsgo::util::threadpool::num_threads() >= 2
}

/// Tentpole, part 1: a panicking decode worker errors exactly its own
/// sequence. Neighbours finish with tokens bit-identical to solo decode,
/// nothing waits out the old 60 s recv, and the supervisor respawns the
/// pool back to width (visible as `worker_restarts` on later responses).
#[test]
fn worker_panic_is_contained_to_one_sequence() {
    let _g = serialize();
    if !pool_is_wide() {
        eprintln!("skipping: step pool would be width 1 on this machine");
        return;
    }
    let m = model(1);
    let prompts: [Vec<u8>; 3] = [vec![10, 20, 30], vec![40, 50, 60], vec![70, 80, 90]];
    let want: Vec<Vec<u8>> = prompts.iter().map(|p| reference(&m, p, 12)).collect();
    // 3 jobs/step: evaluations 1-3 are the prefill step, 4-6 the first
    // decode step — hit 5 panics one worker mid-decode, pooled.
    let cfg = BatcherConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(500),
        step_timeout: Duration::from_secs(5),
        faults: Some(FaultPlan::single(FaultPoint::StepWorkerPanic, 0, 5)),
        ..Default::default()
    };
    let b = Arc::new(DynamicBatcher::spawn(m.clone(), cfg));
    let t0 = Instant::now();
    let handles: Vec<_> = prompts
        .iter()
        .cloned()
        .map(|prompt| {
            let b = b.clone();
            std::thread::spawn(move || {
                b.generate(GenRequest { prompt, max_new: 12, ..Default::default() })
            })
        })
        .collect();
    let results: Vec<Result<GenResponse, _>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(30),
        "containment must not stall the batch (took {elapsed:?})"
    );
    let errs: Vec<String> =
        results.iter().filter_map(|r| r.as_ref().err().map(|e| e.to_string())).collect();
    assert_eq!(errs.len(), 1, "exactly one sequence must error, got {errs:?}");
    assert!(
        errs[0].contains("decode worker panicked") && errs[0].contains("injected fault"),
        "{}",
        errs[0]
    );
    let mut survivors = 0;
    for (i, r) in results.iter().enumerate() {
        if let Ok(resp) = r {
            assert_eq!(resp.tokens, want[i], "neighbour {i}'s tokens changed");
            assert!(
                resp.worker_restarts >= 1,
                "pool was not respawned by the time neighbour {i} finished"
            );
            assert!(!resp.timed_out);
            survivors += 1;
        }
    }
    assert_eq!(survivors, 2);
}

/// Tentpole, part 2: a shard worker death poisons the chain — the in-flight
/// request errors terminally — and the next request triggers a full chain
/// rebuild and succeeds with bit-identical tokens.
#[test]
fn shard_death_rebuilds_the_chain() {
    let _g = serialize();
    let m = model(2);
    let prompt = vec![5u8, 6, 7];
    let want = reference(&m, &prompt, 6);
    let cfg = BatcherConfig {
        shards: 2,
        step_timeout: Duration::from_secs(5),
        faults: Some(FaultPlan::single(FaultPoint::ShardWorkerPanic, 0, 1)),
        ..Default::default()
    };
    let b = DynamicBatcher::spawn(m.clone(), cfg);
    let err = b
        .generate(GenRequest { prompt: prompt.clone(), max_new: 6, ..Default::default() })
        .unwrap_err()
        .to_string();
    assert!(err.contains("shard pipeline"), "{err}");
    // The fault fired exactly once; the rebuilt chain serves normally.
    let r = b.generate(GenRequest { prompt, max_new: 6, ..Default::default() }).unwrap();
    assert_eq!(r.tokens, want, "rebuilt pipeline's tokens diverged");
    assert!(r.pipeline_rebuilds >= 1, "rebuild was not counted");
}

/// Satellite (telemetry): the process-wide registry's recovery counters
/// move in lockstep with provoked recovery, and the values stamped on
/// `GenResponse` are readings of that same registry — a worker panic bumps
/// `worker_restarts`, a shard death bumps `pipeline_rebuilds`, each by the
/// number of recoveries actually performed. CI's chaos leg runs this
/// cross-check alongside the containment battery above.
#[test]
fn recovery_counters_cross_check_registry() {
    let _g = serialize();
    let reg = tsgo::obs::registry();

    // Shard death → pipeline rebuild (works at any pool width).
    let rebuilds_before = reg.pipeline_rebuilds.get();
    let m = model(12);
    let prompt = vec![21u8, 22, 23];
    let cfg = BatcherConfig {
        shards: 2,
        step_timeout: Duration::from_secs(5),
        faults: Some(FaultPlan::single(FaultPoint::ShardWorkerPanic, 0, 1)),
        ..Default::default()
    };
    let b = DynamicBatcher::spawn(m.clone(), cfg);
    let _ = b.generate(GenRequest { prompt: prompt.clone(), max_new: 4, ..Default::default() });
    let r = b
        .generate(GenRequest { prompt, max_new: 4, ..Default::default() })
        .expect("rebuilt chain must serve");
    drop(b);
    let rebuilds_after = reg.pipeline_rebuilds.get();
    assert!(
        rebuilds_after >= rebuilds_before + 1,
        "provoked shard death did not move the registry ({rebuilds_before} → {rebuilds_after})"
    );
    // The response's counter is a registry reading taken at finish time:
    // it must land inside the window the provoked recovery opened.
    assert!(
        (r.pipeline_rebuilds as u64) > rebuilds_before
            && (r.pipeline_rebuilds as u64) <= rebuilds_after,
        "GenResponse.pipeline_rebuilds = {} outside registry window ({rebuilds_before}, {rebuilds_after}]",
        r.pipeline_rebuilds
    );

    // Worker panic → pool respawn (needs a pool wider than the victim).
    if !pool_is_wide() {
        eprintln!("skipping worker-restart leg: step pool would be width 1");
        return;
    }
    let restarts_before = reg.worker_restarts.get();
    let m = model(13);
    let cfg = BatcherConfig {
        max_batch: 2,
        max_wait: Duration::from_millis(500),
        step_timeout: Duration::from_secs(5),
        // 2 jobs/step: evaluations 1-2 prefill, hit 3 panics one worker on
        // the first decode step.
        faults: Some(FaultPlan::single(FaultPoint::StepWorkerPanic, 0, 3)),
        ..Default::default()
    };
    let b = Arc::new(DynamicBatcher::spawn(m, cfg));
    let handles: Vec<_> = [vec![31u8, 32], vec![41u8, 42]]
        .into_iter()
        .map(|prompt| {
            let b = b.clone();
            std::thread::spawn(move || {
                b.generate(GenRequest { prompt, max_new: 12, ..Default::default() })
            })
        })
        .collect();
    let results: Vec<Result<GenResponse, _>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    drop(b);
    let restarts_after = reg.worker_restarts.get();
    assert!(
        restarts_after >= restarts_before + 1,
        "provoked worker panic did not move the registry ({restarts_before} → {restarts_after})"
    );
    assert_eq!(results.iter().filter(|r| r.is_err()).count(), 1);
    for resp in results.into_iter().flatten() {
        assert!(
            (resp.worker_restarts as u64) <= restarts_after,
            "GenResponse.worker_restarts = {} beyond registry value {restarts_after}",
            resp.worker_restarts
        );
    }
}

/// Satellite: a reply lost in flight (`channel_drop`) must not leak the
/// sequence's KV-pool pages — the worker releases the bank at the drop
/// site, the step errors the sequence at the deadline, and after retire
/// the pool reads empty and the slot is reusable.
#[test]
fn lost_reply_releases_pages_and_slot() {
    let _g = serialize();
    let m = model(3);
    let kv = KvSpec::DenseF32;
    let pc = PoolCfg { budget_bytes: 1 << 30, page_tokens: 16 };
    let mut be = LocalBackend::new(m.clone(), kv, 2, Some(pc));
    be.set_step_timeout(Duration::from_millis(100));
    fault::arm(&FaultPlan::single(FaultPoint::ChannelDrop, 0, 1));
    let admit = |be: &mut LocalBackend<ModelWeights>| match be.admit(4) {
        AdmitVerdict::Slot(s) => s,
        _ => panic!("ample pool must admit"),
    };
    let s0 = admit(&mut be);
    let s1 = admit(&mut be);
    let jobs = [
        StepJob { slot: s0, pos: 0, tokens: vec![1, 2, 3, 4] },
        StepJob { slot: s1, pos: 0, tokens: vec![9, 8, 7, 6] },
    ];
    let out = be.step(&jobs);
    fault::disarm();
    let n_err = out.iter().filter(|r| r.is_err()).count();
    assert_eq!(n_err, 1, "exactly the dropped reply's job must error: {out:?}");
    let lost = out.iter().find_map(|r| r.as_ref().err()).unwrap();
    assert!(lost.contains("reply lost"), "{lost}");
    be.retire(s0);
    be.retire(s1);
    let (used, total) = be.pool_stats().expect("pooled backend");
    assert_eq!(used, 0, "lost bank leaked pages ({used}/{total} still held)");
    // The freed slots admit and decode again.
    let s2 = admit(&mut be);
    let out = be.step(&[StepJob { slot: s2, pos: 0, tokens: vec![3, 5] }]);
    assert!(out[0].is_ok(), "reused slot failed: {out:?}");
    be.retire(s2);
    assert_eq!(be.pool_stats().unwrap().0, 0);
}

/// Satellite: a reply that lands *after* its step's deadline parks a live
/// KV bank in the done channel; `reclaim_stale` (run by retire and by every
/// pooled step) must drop it so its pages return exactly once.
#[test]
fn late_reply_bank_is_reclaimed() {
    let _g = serialize();
    let m = model(4);
    let pc = PoolCfg { budget_bytes: 1 << 30, page_tokens: 16 };
    let mut be = LocalBackend::new(m.clone(), KvSpec::DenseF32, 2, Some(pc));
    be.set_step_timeout(Duration::from_millis(100));
    fault::arm(&FaultPlan::single(FaultPoint::StepWorkerSlowMs, 500, 1));
    let (s0, s1) = match (be.admit(2), be.admit(2)) {
        (AdmitVerdict::Slot(a), AdmitVerdict::Slot(b)) => (a, b),
        _ => panic!("ample pool must admit"),
    };
    let out = be.step(&[
        StepJob { slot: s0, pos: 0, tokens: vec![1, 2] },
        StepJob { slot: s1, pos: 0, tokens: vec![3, 4] },
    ]);
    fault::disarm();
    assert!(
        out.iter().any(|r| r.is_err()),
        "the slow job must miss the 100 ms step deadline: {out:?}"
    );
    // Let the slow worker's reply land in the done channel, then reclaim.
    std::thread::sleep(Duration::from_millis(900));
    be.reclaim_stale();
    be.retire(s0);
    be.retire(s1);
    let (used, _) = be.pool_stats().unwrap();
    assert_eq!(used, 0, "late reply's bank leaked {used} pages");
}

/// Satellite: faults composed with pool pressure. An `admit_exhaust` defer
/// plus a mid-run worker panic in one paged run — every request terminates
/// (no hang), the survivor's tokens match solo decode even across
/// preemption replay, and the pool drains to zero pages.
#[test]
fn faults_compose_with_preemption() {
    let _g = serialize();
    if !pool_is_wide() {
        eprintln!("skipping: step pool would be width 1 on this machine");
        return;
    }
    const CHUNK: usize = 48;
    let m = model(11);
    let kv = KvSpec::DenseF32;
    // Same sizing as the scheduler's preemption test: a 16-unit pool that
    // two sequences (one with a 200-token prompt) are sized to overflow.
    let probe = KvPool::new(
        PoolCfg { budget_bytes: 1 << 30, page_tokens: 16 },
        kv,
        m.config(),
    );
    let pc = PoolCfg {
        budget_bytes: 16 * 2 * m.config().n_layers * probe.page_bytes(),
        page_tokens: 16,
    };
    let prompt_a: Vec<u8> = (0..8u8).collect();
    let prompt_b: Vec<u8> = (0..200u32).map(|i| (i * 7 % 251) as u8).collect();
    let want_a = reference(&m, &prompt_a, 60);
    let want_b = reference(&m, &prompt_b, 24);
    let (tx, rx) = channel::<Pending>();
    let (ra_tx, ra_rx) = channel();
    let (rb_tx, rb_rx) = channel();
    let now = Instant::now();
    tx.send(Pending {
        req: GenRequest { prompt: prompt_a, max_new: 60, ..Default::default() },
        enqueued: now,
        reply: ra_tx,
        events: None,
    })
    .unwrap();
    tx.send(Pending {
        req: GenRequest { prompt: prompt_b, max_new: 24, ..Default::default() },
        enqueued: now,
        reply: rb_tx,
        events: None,
    })
    .unwrap();
    let cfg = BatcherConfig {
        max_batch: 2,
        max_wait: Duration::from_secs(1),
        kv,
        pool: Some(pc),
        prefill_chunk: CHUNK,
        step_timeout: Duration::from_secs(5),
        ..Default::default()
    };
    // Defer the very first admission once, then panic a step worker around
    // the 20th batch step — mid-decode, likely after a preemption.
    fault::arm(
        &FaultPlan::single(FaultPoint::AdmitExhaust, 0, 1)
            .with(FaultPoint::StepWorkerPanic, 0, 40),
    );
    let sched = std::thread::spawn(move || {
        let mut backend = LocalBackend::new(m, kv, 2, Some(pc));
        scheduler_loop(&mut backend, &cfg, RequestQueue::for_tests(rx));
        backend
    });
    let resp_a = ra_rx.recv().unwrap();
    let resp_b = rb_rx.recv().unwrap();
    drop(tx);
    let backend = sched.join().unwrap();
    fault::disarm();
    let n_err = [&resp_a, &resp_b].iter().filter(|r| r.is_err()).count();
    assert_eq!(
        n_err, 1,
        "the one injected panic must kill exactly one request: {resp_a:?} / {resp_b:?}"
    );
    // Whichever survived must have decoded its exact solo tokens —
    // preemption replay included.
    match (&resp_a, &resp_b) {
        (Ok(a), Err(e)) => {
            assert_eq!(a.tokens, want_a, "survivor A's tokens changed");
            assert!(e.contains("panick"), "{e}");
        }
        (Err(e), Ok(b)) => {
            assert_eq!(b.tokens, want_b, "survivor B's tokens changed");
            assert!(e.contains("panick"), "{e}");
        }
        other => panic!("expected one Ok and one Err, got {other:?}"),
    }
    // No slot or page leaked through the panic + preemption churn.
    let (used, total) = backend.pool_stats().unwrap();
    assert_eq!(used, 0, "pool still holds {used}/{total} pages after drain");
}

/// Tentpole, part 3a: `--request-timeout` retires an in-flight sequence at
/// its deadline with the tokens generated so far and `timed_out` set.
#[test]
fn request_deadline_returns_partial_tokens() {
    let _g = serialize();
    let m = model(5);
    let cfg = BatcherConfig {
        request_timeout: Some(Duration::from_millis(150)),
        ..Default::default()
    };
    let b = DynamicBatcher::spawn(m, cfg);
    let t0 = Instant::now();
    let r = b
        .generate(GenRequest { prompt: vec![2, 4, 6, 8], max_new: 500_000, ..Default::default() })
        .unwrap();
    assert!(r.timed_out, "an unfinishable request must report timed_out");
    assert!(
        !r.tokens.is_empty() && r.tokens.len() < 500_000,
        "expected partial tokens, got {}",
        r.tokens.len()
    );
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "deadline did not bound the request ({:?})",
        t0.elapsed()
    );
}

/// Tentpole, part 3b: the deadline covers queue wait too — a request stuck
/// behind a slot-hogging neighbour times out without ever decoding.
#[test]
fn request_deadline_covers_queue_wait() {
    let _g = serialize();
    let m = model(6);
    let cfg = BatcherConfig {
        max_batch: 1,
        request_timeout: Some(Duration::from_millis(120)),
        ..Default::default()
    };
    let b = Arc::new(DynamicBatcher::spawn(m, cfg));
    let handles: Vec<_> = (0..2u8)
        .map(|i| {
            let b = b.clone();
            std::thread::spawn(move || {
                b.generate(GenRequest {
                    prompt: vec![i + 1, i + 2],
                    max_new: 500_000,
                    ..Default::default()
                })
                .unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert!(r.timed_out, "both the runner and the queued request must time out");
    }
}

/// Tentpole, part 3c: `--step-timeout` replaces the hardcoded 60 s reply
/// wait — a wedged worker errors only its own sequence, fast, and the
/// neighbour decodes its exact reference tokens.
#[test]
fn step_timeout_bounds_a_wedged_worker() {
    let _g = serialize();
    if !pool_is_wide() {
        eprintln!("skipping: step pool would be width 1 on this machine");
        return;
    }
    let m = model(7);
    let prompts: [Vec<u8>; 2] = [vec![11, 13], vec![17, 19]];
    let want: Vec<Vec<u8>> = prompts.iter().map(|p| reference(&m, p, 8)).collect();
    // Evaluations 1-2 are the prefill step; hit 3 wedges one decode job
    // for 800 ms against a 150 ms step deadline.
    let cfg = BatcherConfig {
        max_batch: 2,
        max_wait: Duration::from_millis(500),
        step_timeout: Duration::from_millis(150),
        faults: Some(FaultPlan::single(FaultPoint::StepWorkerSlowMs, 800, 3)),
        ..Default::default()
    };
    let b = Arc::new(DynamicBatcher::spawn(m.clone(), cfg));
    let t0 = Instant::now();
    let handles: Vec<_> = prompts
        .iter()
        .cloned()
        .map(|prompt| {
            let b = b.clone();
            std::thread::spawn(move || {
                b.generate(GenRequest { prompt, max_new: 8, ..Default::default() })
            })
        })
        .collect();
    let results: Vec<Result<GenResponse, _>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "step deadline did not bound the wedge ({:?})",
        t0.elapsed()
    );
    let errs: Vec<String> =
        results.iter().filter_map(|r| r.as_ref().err().map(|e| e.to_string())).collect();
    assert_eq!(errs.len(), 1, "exactly the wedged sequence must error: {errs:?}");
    assert!(errs[0].contains("reply lost"), "{}", errs[0]);
    for (i, r) in results.iter().enumerate() {
        if let Ok(resp) = r {
            assert_eq!(resp.tokens, want[i], "neighbour {i}'s tokens changed");
        }
    }
}

/// The env arming path CI's chaos leg rides on, plus the unarmed-plane
/// contract every hot path relies on.
#[test]
fn env_arming_and_unarmed_plane() {
    let _g = serialize();
    fault::disarm();
    assert!(!fault::armed());
    assert_eq!(fault::fire(FaultPoint::StepWorkerPanic), None);
    std::env::set_var("TSGO_FAULT", "step_worker_slow_ms=1@hit=1000000000");
    assert!(fault::arm_from_env(), "a valid TSGO_FAULT must arm the plane");
    assert!(fault::armed());
    // Armed-but-idle: a huge hit count means evaluations count but never
    // fire — the configuration the bench uses for the overhead row.
    assert_eq!(fault::fire(FaultPoint::StepWorkerSlowMs), None);
    std::env::set_var("TSGO_FAULT", "not_a_point");
    assert!(!fault::arm_from_env(), "a malformed spec must be a loud no-op");
    assert!(fault::armed(), "malformed spec must not clobber the armed plan");
    std::env::remove_var("TSGO_FAULT");
    assert!(!fault::arm_from_env(), "unset var leaves state alone, reports unarmed");
    fault::disarm();
    assert!(!fault::armed());
}
