//! End-to-end telemetry-plane battery: a live server answers the
//! `{"stats": true}` control line and a Prometheus scrape, and the
//! process-wide registry's counters cross-check against the summed
//! per-request `GenResponse` fields of a multi-client run.
//!
//! The registry is process-global (one static per process, like the fault
//! plane), so every test here serializes on one mutex and asserts *deltas*
//! (value after minus value before) — exact-equality assertions on the
//! absolute values would couple the tests to execution order.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

use tsgo::model::{ModelWeights, Preset};
use tsgo::obs::{registry, serve_metrics};
use tsgo::serve::client::ClientResponse;
use tsgo::serve::{
    request_generation, request_stats, server::serve_in_background, ServerConfig,
};
use tsgo::util::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn serialize() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn model(seed: u64) -> Arc<ModelWeights> {
    let mut rng = Rng::new(seed);
    Arc::new(ModelWeights::init(Preset::Tiny.config(), &mut rng))
}

/// Run `budgets.len()` concurrent clients against a fresh server (one
/// connection each), plus one `{"stats": true}` connection at the end.
/// Returns the responses and the parsed stats line.
fn run_clients(
    seed: u64,
    budgets: &[usize],
) -> (Vec<ClientResponse>, tsgo::util::json::Json) {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_connections: Some(budgets.len() + 1),
        ..Default::default()
    };
    let (addr, handle) = serve_in_background(model(seed), cfg).unwrap();
    let threads: Vec<_> = budgets
        .iter()
        .enumerate()
        .map(|(i, &max_new)| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let prompt = [i as u8 + 1, i as u8 + 2, i as u8 + 3];
                request_generation(&addr, &prompt, max_new).unwrap()
            })
        })
        .collect();
    let responses: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    let stats = request_stats(&addr.to_string()).unwrap();
    handle.join().unwrap();
    (responses, stats)
}

/// The spine: counters scraped from the live server equal what the summed
/// per-request responses imply. `decode_tokens` counts one increment per
/// emitted token by construction (a span ending at the chain end samples
/// exactly one token), so its delta must equal the total tokens the
/// clients received — the invariant that makes the plane trustworthy.
#[test]
fn stats_line_cross_checks_summed_responses() {
    let _g = serialize();
    let reg = registry();
    let decode_before = reg.decode_tokens.get();
    let prefill_before = reg.prefill_tokens.get();
    let steps_before = reg.steps.get();
    let length_before = reg.finish_length.get();
    let ok_before = reg.requests_ok.get();
    let conns_before = reg.connections_total.get();
    let step_hist_before = reg.step_ms.snapshot().count;
    let prefill_hist_before = reg.request_prefill_ms.snapshot().count;
    let queue_depth_before = reg.queue_depth.get();

    let budgets = [4usize, 5, 6];
    let (responses, stats) = run_clients(21, &budgets);

    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    assert_eq!(total_tokens, budgets.iter().sum::<usize>());
    assert!(responses.iter().all(|r| r.finish_reason == "length"));

    // Counter deltas vs summed responses. Prompts are 3 tokens: the prefill
    // span's last token is decode-fed (it samples token 1), so each request
    // contributes prompt_len - 1 = 2 prefill tokens and max_new decode
    // tokens.
    assert_eq!(reg.decode_tokens.get() - decode_before, total_tokens as u64);
    assert_eq!(reg.prefill_tokens.get() - prefill_before, 2 * budgets.len() as u64);
    assert_eq!(reg.finish_length.get() - length_before, budgets.len() as u64);
    assert_eq!(reg.requests_ok.get() - ok_before, budgets.len() as u64);
    // 3 generation connections + 1 stats connection.
    assert_eq!(reg.connections_total.get() - conns_before, budgets.len() as u64 + 1);
    // Steps: at best every request shares every step (max budget = 6 steps),
    // at worst nothing batches (sum of budgets = 15 steps).
    let steps_delta = reg.steps.get() - steps_before;
    assert!((6..=15).contains(&steps_delta), "steps delta {steps_delta}");
    // One histogram observation per step / per finished request.
    assert_eq!(reg.step_ms.snapshot().count - step_hist_before, steps_delta);
    assert_eq!(
        reg.request_prefill_ms.snapshot().count - prefill_hist_before,
        budgets.len() as u64
    );
    // Every request settled: the queue-depth gauge is back where it started.
    assert_eq!(reg.queue_depth.get(), queue_depth_before);

    // The stats line is a faithful snapshot of the same registry.
    let counters = stats.get("counters");
    assert_eq!(
        counters.get("decode_tokens").as_f64().unwrap() as u64,
        reg.decode_tokens.get()
    );
    assert_eq!(
        counters.get("requests_ok").as_f64().unwrap() as u64,
        reg.requests_ok.get()
    );
    assert!(stats.get("gauges").get("kv_pages_used").as_f64().is_some());
    let step_hist = stats.get("hist").get("step_ms");
    assert!(step_hist.get("count").as_f64().unwrap() >= steps_delta as f64);
    let (p50, p95, p99) = (
        step_hist.get("p50_ms").as_f64().unwrap(),
        step_hist.get("p95_ms").as_f64().unwrap(),
        step_hist.get("p99_ms").as_f64().unwrap(),
    );
    assert!(p50 <= p95 && p95 <= p99, "quantiles out of order: {p50} {p95} {p99}");
    let trace = stats.get("trace").as_arr().expect("trace array");
    assert!(!trace.is_empty(), "step trace must have recorded events");
    // Responses carry the registry's (process-lifetime) recovery counters.
    for r in &responses {
        assert!(r.worker_restarts as u64 <= reg.worker_restarts.get());
        assert!(r.pipeline_rebuilds as u64 <= reg.pipeline_rebuilds.get());
    }
}

/// The `--metrics-addr` surface: a raw HTTP GET against the dedicated
/// listener returns Prometheus text exposition whose counter values match
/// the registry, with the gauge and histogram families the acceptance
/// criteria name.
#[test]
fn metrics_listener_scrapes_during_serving() {
    let _g = serialize();
    // The exact listener `tsgo serve --metrics-addr` spawns (ServerConfig
    // routes through the same function); port 0 so the test learns the port.
    let maddr = serve_metrics("127.0.0.1:0").unwrap();

    let budgets = [3usize, 4];
    let (responses, _) = run_clients(22, &budgets);
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    assert_eq!(total_tokens, 7);

    let mut sock = TcpStream::connect(maddr).unwrap();
    sock.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut raw = String::new();
    sock.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.0 200"), "bad status: {}", raw.lines().next().unwrap_or(""));
    let body = raw.split_once("\r\n\r\n").expect("header/body split").1;

    // Families the acceptance criteria name: queue depth, pool occupancy,
    // step/prefill/decode histograms, fault-recovery counters.
    for needle in [
        "# TYPE tsgo_queue_depth gauge",
        "# TYPE tsgo_kv_pages_used gauge",
        "# TYPE tsgo_step_latency_ms histogram",
        "# TYPE tsgo_request_prefill_ms histogram",
        "# TYPE tsgo_request_decode_ms histogram",
        "tsgo_worker_restarts_total",
        "tsgo_pipeline_rebuilds_total",
        "tsgo_step_latency_ms_bucket{le=\"+Inf\"}",
        "tsgo_requests_total{outcome=\"ok\"}",
    ] {
        assert!(body.contains(needle), "scrape missing {needle:?}");
    }

    // Scraped values are the registry's values (nothing steps concurrently
    // here: the server drained before the scrape, and the lock holds).
    let reg = registry();
    let scraped = |name: &str| -> f64 {
        body.lines()
            .find(|l| l.starts_with(name) && l.as_bytes().get(name.len()) == Some(&b' '))
            .unwrap_or_else(|| panic!("no sample line for {name}"))
            .split_whitespace()
            .nth(1)
            .unwrap()
            .parse()
            .unwrap()
    };
    assert_eq!(scraped("tsgo_steps_total") as u64, reg.steps.get());
    assert_eq!(scraped("tsgo_decode_tokens_total") as u64, reg.decode_tokens.get());
    assert_eq!(scraped("tsgo_connections_total") as u64, reg.connections_total.get());

    // Unknown paths 404 without killing the listener.
    let mut sock = TcpStream::connect(maddr).unwrap();
    sock.write_all(b"GET /nope HTTP/1.0\r\n\r\n").unwrap();
    let mut raw = String::new();
    sock.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.0 404"), "{raw}");
    let mut sock = TcpStream::connect(maddr).unwrap();
    sock.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut reader = BufReader::new(sock);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("HTTP/1.0 200"), "listener died after 404: {line}");
}
