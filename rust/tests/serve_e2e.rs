//! Serving-path integration: quantize → serve → client round-trip, and
//! FP-vs-quantized generation agreement at moderate bit widths.

use std::sync::Arc;
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::model::{ModelWeights, Preset};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantSpec;
use tsgo::serve::{request_generation, server::serve_in_background, ServerConfig};
use tsgo::util::rng::Rng;

#[test]
fn quantized_model_serves_requests() {
    let cfg = Preset::Tiny.config();
    let mut rng = Rng::new(77);
    let w = ModelWeights::init(cfg, &mut rng);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
    let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
    let (qm, _) = quantize_model(
        &w,
        &calib,
        &PipelineConfig::new(QuantSpec::new(4, 32), "ours"),
    )
    .unwrap();

    let server_cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_connections: Some(2),
        ..Default::default()
    };
    let (addr, handle) = serve_in_background(Arc::new(qm.weights), server_cfg).unwrap();
    let a = request_generation(&addr.to_string(), &[65, 66, 67], 6).unwrap();
    assert_eq!(a.tokens.len(), 6);
    let b = request_generation(&addr.to_string(), &[65, 66, 67], 6).unwrap();
    assert_eq!(a.tokens, b.tokens, "greedy generation must be deterministic");
    handle.join().unwrap();
}

#[test]
fn int8_generation_tracks_fp() {
    // At 8 bits the quantized model should almost always pick the same
    // greedy tokens as FP for a short horizon.
    let cfg = Preset::Tiny.config();
    let mut rng = Rng::new(88);
    let w = ModelWeights::init(cfg, &mut rng);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 2);
    let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
    let (qm, _) = quantize_model(
        &w,
        &calib,
        &PipelineConfig::new(QuantSpec::new(8, 64), "ours"),
    )
    .unwrap();

    let gen = |weights: &ModelWeights| -> Vec<u8> {
        let mut st = tsgo::model::DecodeState::new(weights);
        let mut logits = Vec::new();
        for &t in &[10u8, 20, 30, 40] {
            logits = st.step(t);
        }
        let mut out = Vec::new();
        for _ in 0..8 {
            let next = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u8;
            out.push(next);
            logits = st.step(next);
        }
        out
    };
    let fp = gen(&w);
    let q = gen(&qm.weights);
    let agree = fp.iter().zip(&q).filter(|(a, b)| a == b).count();
    assert!(agree >= 6, "INT8 generation diverged: {fp:?} vs {q:?}");
}
