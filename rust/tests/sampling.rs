//! Decoding stack: the PR-9 acceptance battery.
//!
//! The sampler chain's spine is *replay invariance*: a seeded request is a
//! pure function of (model, prompt, params), because the logits it samples
//! from are bit-identical across kernel tables, prefill chunk sizes, and
//! shard counts, and the chain consumes exactly one RNG draw per emitted
//! token. This file pins that, plus the greedy default's bit-identity to
//! the historical argmax path, stop-sequence termination, the streaming
//! event contract over real TCP, and cancellation (a dropped stream must
//! retire its slot and free its KV-pool pages).

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::kvpool::{KvPool, PoolCfg};
use tsgo::model::{DecodeState, ExecModel, KvSpec, ModelConfig, ModelExec, ModelWeights};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantPlan;
use tsgo::serve::{
    argmax_token, request_generation_streaming, request_generation_with, server::serve_in_background,
    BatcherConfig, ClientOptions, DynamicBatcher, FinishReason, GenRequest, SamplingParams,
    ServerConfig, StreamHandle,
};
use tsgo::tensor::kernels::{set_forced, ForcedKernel};
use tsgo::util::rng::Rng;

/// Serializes tests that flip the process-wide forced-kernel state or make
/// token-exact cross-run comparisons (same pattern as
/// `tests/chunked_prefill.rs`): a concurrent flip mid-decode would make a
/// real scalar/SIMD divergence nondeterministic.
fn force_lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// A 4-layer tiny-width config so 2-shard plans are a real split.
fn cfg4() -> ModelConfig {
    ModelConfig { vocab: 256, d_model: 64, n_layers: 4, n_heads: 2, ffn: 128, seq_len: 96 }
}

fn dense4(seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    ModelWeights::init(cfg4(), &mut rng)
}

/// Mixed-precision packed checkpoint (2/3/4/8-bit linears in one model):
/// every specialized dequant width under the sampler at once.
fn mixed_packed4() -> ExecModel {
    let w = dense4(79);
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 30_000, 1);
    let calib = calibration_batches(&corpus.bytes, 4, 32, 2, 3);
    let plan = QuantPlan::parse_with_defaults(
        "rtn:bits=2,group=32;wv=bits3;wo=bits4;w2=bits8",
        4,
        32,
    )
    .unwrap();
    let (qm, _) = quantize_model(&w, &calib, &PipelineConfig::from_plan(plan)).unwrap();
    ExecModel::from_quantized(&qm)
}

fn prompt() -> Vec<u8> {
    (0..24u32).map(|i| (i * 37 % 251) as u8).collect()
}

/// A chain that exercises every transform: repetition penalty, temperature,
/// top-k, top-p, then the seeded multinomial selector.
fn sampled(seed: u64) -> SamplingParams {
    SamplingParams {
        temperature: 0.9,
        top_k: 20,
        top_p: 0.9,
        repetition_penalty: 1.15,
        seed,
    }
}

/// Greedy reference decode through a plain [`DecodeState`] — the historical
/// pre-sampler path the default request must reproduce byte for byte.
fn greedy_direct<M: ModelExec>(m: &M, kv: KvSpec, prompt: &[u8], max_new: usize) -> Vec<u8> {
    let mut st = DecodeState::with_kv(m, kv);
    let mut logits = Vec::new();
    for &t in prompt {
        logits = st.step(t);
    }
    let mut out = Vec::new();
    for _ in 0..max_new {
        let next = argmax_token(&logits).unwrap();
        out.push(next);
        logits = st.step(next);
    }
    out
}

#[test]
fn seeded_sampling_replays_identically_across_the_whole_matrix() {
    let _guard = force_lock();
    // The tentpole acceptance bar: one seeded request, one token stream —
    // across repeated runs on the same batcher, every `--prefill-chunk`
    // value, shard counts 1 and 2, and the dispatched vs forced-scalar
    // kernel tables. Any divergence means a logit bit changed or an RNG
    // draw was consumed at the wrong step.
    let m = Arc::new(mixed_packed4());
    let req = GenRequest {
        prompt: prompt(),
        max_new: 12,
        params: sampled(42),
        ..Default::default()
    };
    let mut want: Option<Vec<u8>> = None;
    for force in [ForcedKernel::Scalar, ForcedKernel::Best] {
        set_forced(force);
        for shards in [1usize, 2] {
            for chunk in [1usize, 3, 64] {
                let b = DynamicBatcher::spawn(
                    m.clone(),
                    BatcherConfig { shards, prefill_chunk: chunk, ..Default::default() },
                );
                let r1 = b.generate(req.clone()).unwrap();
                let r2 = b.generate(req.clone()).unwrap();
                assert_eq!(r1.tokens.len(), 12);
                assert_eq!(r1.finish_reason, FinishReason::Length);
                assert_eq!(
                    r1.tokens, r2.tokens,
                    "{force:?} shards={shards} chunk={chunk}: same seed, two runs diverged"
                );
                match &want {
                    None => want = Some(r1.tokens),
                    Some(w) => assert_eq!(
                        &r1.tokens, w,
                        "{force:?} shards={shards} chunk={chunk} diverged from the baseline cell"
                    ),
                }
            }
        }
    }
    set_forced(ForcedKernel::Auto);
}

#[test]
fn greedy_default_is_bit_identical_to_direct_argmax_decode() {
    let _guard = force_lock();
    // A default-params request through the whole serving stack must emit
    // exactly the tokens of a bare DecodeState + argmax loop: the sampler
    // chain's greedy path may not touch a logit.
    let m = Arc::new(mixed_packed4());
    let want = greedy_direct(&*m, KvSpec::DenseF32, &prompt(), 10);
    let b = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
    let r = b
        .generate(GenRequest { prompt: prompt(), max_new: 10, ..Default::default() })
        .unwrap();
    assert_eq!(r.tokens, want, "greedy default diverged from the pre-sampler argmax path");
    assert_eq!(r.finish_reason, FinishReason::Length);
}

#[test]
fn stop_sequence_ends_generation_with_finish_reason_stop() {
    let _guard = force_lock();
    // Learn the greedy stream, then replay with a stop sequence cut from
    // its middle: generation must end exactly where the stop run first
    // completes, with the matched run still in the output (so streamed
    // events always concatenate to the final tokens).
    let m = Arc::new(dense4(23));
    let b = DynamicBatcher::spawn(m.clone(), BatcherConfig::default());
    let full = b
        .generate(GenRequest { prompt: prompt(), max_new: 12, ..Default::default() })
        .unwrap()
        .tokens;
    assert_eq!(full.len(), 12);
    let stop = full[3..6].to_vec();
    let cut = (1..=full.len())
        .find(|&k| full[..k].ends_with(&stop))
        .expect("stop cut from the stream must occur in it");
    let r = b
        .generate(GenRequest {
            prompt: prompt(),
            max_new: 12,
            stop: vec![stop.clone()],
            ..Default::default()
        })
        .unwrap();
    assert_eq!(r.finish_reason, FinishReason::Stop);
    assert_eq!(r.tokens, &full[..cut], "generation must end where the stop run completes");
    assert!(r.tokens.ends_with(&stop), "the matched stop run stays in the output");

    // A single-token stop fires on the very first emission.
    let r1 = b
        .generate(GenRequest {
            prompt: prompt(),
            max_new: 12,
            stop: vec![vec![full[0]]],
            ..Default::default()
        })
        .unwrap();
    assert_eq!(r1.finish_reason, FinishReason::Stop);
    assert_eq!(r1.tokens, &full[..1]);
}

#[test]
fn streaming_events_concatenate_to_the_final_response() {
    let _guard = force_lock();
    // Real TCP: a `"stream": true` request yields one `{"token","index"}`
    // event line per sampled token, in order, and the terminal line's
    // `tokens` equals the concatenated events. A blocking request with the
    // same seed gets the same stream — replay invariance over the wire.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_connections: Some(2),
        ..Default::default()
    };
    let (addr, handle) = serve_in_background(Arc::new(dense4(24)), cfg).unwrap();
    let opts = ClientOptions { params: Some(sampled(7)), stop: Vec::new() };
    let mut events: Vec<u8> = Vec::new();
    let resp = request_generation_streaming(&addr.to_string(), &[65, 66, 67], 12, &opts, |t, i| {
        assert_eq!(i, events.len(), "event indices must be sequential from 0");
        events.push(t);
    })
    .unwrap();
    assert_eq!(resp.tokens.len(), 12);
    assert_eq!(resp.finish_reason, "length");
    assert_eq!(events, resp.tokens, "streamed events must concatenate to the final tokens");
    let blocking = request_generation_with(&addr.to_string(), &[65, 66, 67], 12, &opts).unwrap();
    assert_eq!(blocking.tokens, resp.tokens, "same seed, streaming vs blocking diverged");
    handle.join().unwrap();
}

#[test]
fn dropped_stream_cancels_the_request_and_frees_its_pool_pages() {
    let _guard = force_lock();
    // A pool sized for one full-length sequence: request A streams, we
    // drop its event receiver mid-decode, and the scheduler must retire
    // the slot *without* replying (cancellation, not completion) — then a
    // second full-length request fits, proving A's pages went back to the
    // free list.
    let kv = KvSpec::DenseF32;
    let cfg = cfg4();
    let page_tokens = 8usize;
    // One 3-prompt + 60-token sequence needs ceil(63/8) = 8 pages; 10
    // pages fit one such sequence but never two.
    let probe = KvPool::new(
        PoolCfg { budget_bytes: 1 << 30, page_tokens },
        kv,
        &cfg,
    );
    let pc = PoolCfg { budget_bytes: 10 * probe.page_bytes(), page_tokens };
    let m = Arc::new(dense4(25));
    let b = DynamicBatcher::spawn(
        m.clone(),
        BatcherConfig { pool: Some(pc), ..Default::default() },
    );
    let small_prompt = vec![5u8, 6, 7];
    let a = b
        .generate_stream(GenRequest {
            prompt: small_prompt.clone(),
            max_new: 60,
            ..Default::default()
        })
        .unwrap();
    // First sampled token: A is admitted, mid-decode, holding pages.
    let first = a.events.recv().expect("first streamed token");
    let StreamHandle { events, reply } = a;
    drop(events);
    // The scheduler hits the closed event channel at A's next token and
    // cancels: slot retired, pages freed, and — the observable contract —
    // the reply channel closes with no response ever sent.
    assert!(
        reply.recv().is_err(),
        "a cancelled request must not produce a response"
    );
    let r = b
        .generate(GenRequest {
            prompt: small_prompt.clone(),
            max_new: 60,
            ..Default::default()
        })
        .unwrap();
    assert_eq!(r.tokens.len(), 60, "the freed pool must fit a second full sequence");
    assert_eq!(r.tokens[0], first, "greedy decode is deterministic across the cancel");
    assert_eq!(r.finish_reason, FinishReason::Length);
    assert_eq!(r.preemptions, 0, "a one-sequence pool with A gone needs no preemption");
}
