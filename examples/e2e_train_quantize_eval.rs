//! **End-to-end driver** — proves all three layers compose on a real small
//! workload:
//!
//! 1. load the AOT artifacts (L2 JAX model + L1 Pallas kernels, compiled
//!    through PJRT);
//! 2. train a Llamette from scratch on the synthetic corpus with the fused
//!    `train_step` artifact, logging the loss curve;
//! 3. evaluate FP perplexity on both held-out corpora (artifact forward);
//! 4. quantize with stock GPTQ and with the paper's method (L3 pipeline);
//! 5. evaluate both quantized models (PPL + 0-shot) and print the
//!    Table-1-shaped comparison.
//!
//! Run: `make artifacts && cargo run --release --example e2e_train_quantize_eval`
//! (Results recorded in EXPERIMENTS.md.)

use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::eval::tasks::{build_suite, task_suite};
use tsgo::model::store;
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantSpec;
use tsgo::runtime::{Engine, TrainConfig};
use tsgo::util::bench::Table;

fn main() -> tsgo::Result<()> {
    let steps: usize = std::env::var("TSGO_E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let engine = Engine::open_default()
        .ok_or_else(|| anyhow::anyhow!("artifacts missing — run `make artifacts` first"))?;
    let cfg = engine.manifest.config;
    println!(
        "== e2e: train ({} steps) → quantize → eval on {:.2}M-param Llamette ==",
        steps,
        cfg.n_params() as f64 / 1e6
    );

    // ---- data ---------------------------------------------------------------
    let wiki = Corpus::generate(CorpusKind::SynthWiki, 400_000, 1);
    let c4 = Corpus::generate(CorpusKind::SynthC4, 200_000, 1);
    let (train_split, wiki_test) = wiki.split(0.1);
    let (_, c4_test) = c4.split(0.2);

    // ---- train ----------------------------------------------------------------
    let t0 = std::time::Instant::now();
    let outcome = tsgo::runtime::train(
        &engine,
        train_split,
        &TrainConfig { steps, seed: 7, log_every: 50 },
    )?;
    println!(
        "trained in {} — loss {:.3} → {:.3}",
        tsgo::util::fmt_duration(t0.elapsed()),
        outcome.losses.first().unwrap(),
        outcome.losses.last().unwrap()
    );
    let fp = outcome.weights;
    store::save_model(std::path::Path::new("model.tsr"), &fp)?;

    // ---- calibration + eval setup ------------------------------------------
    let calib = calibration_batches(train_split, 16, cfg.seq_len, 4, 3);
    let windows = 24;
    let items = build_suite(&wiki, 20, 17);

    let eval_ppl = |w: &tsgo::model::ModelWeights, data: &[u8]| -> f64 {
        tsgo::runtime::perplexity_artifact(&engine, w, data, cfg.seq_len, windows)
            .unwrap_or_else(|_| tsgo::eval::perplexity(w, data, cfg.seq_len, windows))
    };

    let mut table = Table::new(&[
        "precision",
        "method",
        "synthwiki ppl",
        "synthc4 ppl",
        "0-shot avg",
        "quant time",
    ]);
    let ppl_w = eval_ppl(&fp, wiki_test);
    let ppl_c = eval_ppl(&fp, c4_test);
    let zs = task_suite(&fp, &items);
    table.row(vec![
        "FP32".into(),
        "baseline".into(),
        format!("{ppl_w:.3}"),
        format!("{ppl_c:.3}"),
        format!("{:.2}", zs.average),
        "-".into(),
    ]);

    // ---- quantize + eval ------------------------------------------------------
    for bits in [2u8, 3] {
        for method in ["gptq", "ours"] {
            let spec = QuantSpec::new(bits, 64);
            let t0 = std::time::Instant::now();
            let (qm, report) =
                quantize_model(&fp, &calib, &PipelineConfig::new(spec, method))?;
            let dt = t0.elapsed();
            let ppl_w = eval_ppl(&qm.weights, wiki_test);
            let ppl_c = eval_ppl(&qm.weights, c4_test);
            let zs = task_suite(&qm.weights, &items);
            println!(
                "  INT{bits} {:<8} layer-loss {:.3e}  ppl {:.2}/{:.2}",
                method,
                report.total_loss(),
                ppl_w,
                ppl_c
            );
            table.row(vec![
                format!("INT{bits}"),
                method.into(),
                format!("{ppl_w:.3}"),
                format!("{ppl_c:.3}"),
                format!("{:.2}", zs.average),
                tsgo::util::fmt_duration(dt),
            ]);
            if bits == 2 && method == "ours" {
                store::save_quantized(std::path::Path::new("model.q.tsr"), &qm)?;
            }
        }
    }

    table.print("e2e results (Table-1 shape, group=64)");
    println!("checkpoints: model.tsr (FP), model.q.tsr (INT2 ours)");
    Ok(())
}
