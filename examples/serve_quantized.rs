//! Serving demo: quantize a model, start the batched generation server, and
//! fire concurrent client requests at it — reporting latency percentiles and
//! token throughput for FP vs INT2.
//!
//! Run: `cargo run --release --example serve_quantized`

use std::sync::Arc;
use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::model::{ExecModel, ModelExec, ModelWeights, Preset};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantSpec;
use tsgo::serve::server::serve_in_background;
use tsgo::serve::{request_generation, BatcherConfig, ServerConfig};
use tsgo::util::rng::Rng;

fn drive<M: ModelExec + Send + Sync + 'static>(
    label: &str,
    weights: Arc<M>,
    n_clients: usize,
    max_new: usize,
) {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        batcher: BatcherConfig::default(),
        max_connections: Some(n_clients),
        ..Default::default()
    };
    let (addr, handle) = serve_in_background(weights, cfg).expect("bind server");
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 20_000, 9);

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for i in 0..n_clients {
        let addr = addr.to_string();
        let prompt: Vec<u8> = corpus.bytes[i * 100..i * 100 + 24].to_vec();
        joins.push(std::thread::spawn(move || {
            request_generation(&addr, &prompt, max_new).expect("request")
        }));
    }
    let responses: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let wall = t0.elapsed();

    let lat: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap_or(1);
    println!(
        "{label:<10} {n_clients} clients × {max_new} tokens: {:.1} tok/s, p50 {:.1}ms p95 {:.1}ms (max batch {max_batch})",
        total_tokens as f64 / wall.as_secs_f64(),
        tsgo::util::percentile(&lat, 50.0),
        tsgo::util::percentile(&lat, 95.0),
    );
    handle.join().unwrap();
}

fn main() -> tsgo::Result<()> {
    // Use a trained checkpoint when present (from the e2e example), else
    // fall back to a fresh init — serving behaviour is the same.
    let fp = match tsgo::model::store::load_model(std::path::Path::new("model.tsr")) {
        Ok(w) => {
            println!("using trained checkpoint model.tsr");
            w
        }
        Err(_) => {
            println!("no model.tsr — using random init (run the e2e example to train one)");
            let mut rng = Rng::new(5);
            ModelWeights::init(Preset::Tiny.config(), &mut rng)
        }
    };

    let corpus = Corpus::generate(CorpusKind::SynthWiki, 100_000, 1);
    let calib = calibration_batches(&corpus.bytes, 8, fp.config.seq_len.min(64), 4, 3);
    println!("quantizing to INT2 (group 64) with the paper's method…");
    let (qm, _) = quantize_model(
        &fp,
        &calib,
        &PipelineConfig::new(QuantSpec::new(2, 64), "ours"),
    )?;
    let packed_mb = qm.packed_bytes() as f64 / 1e6;
    let fp_mb = (fp.config.n_params() * 4) as f64 / 1e6;
    println!("weights: {fp_mb:.1} MB fp32 → {packed_mb:.1} MB packed\n");

    let clients = 8;
    let packed = ExecModel::from_quantized(&qm);
    let byte_ratio =
        packed.dense_linear_bytes() as f64 / packed.linear_weight_bytes() as f64;
    drive("FP32", Arc::new(fp), clients, 32);
    drive("INT2", Arc::new(qm.weights), clients, 32);
    drive("INT2-pack", Arc::new(packed), clients, 32);
    println!(
        "\n(INT2 dequantizes at load; INT2-pack executes the packed ints through the\n fused group-wise dequant kernels — `tsgo serve --packed` — touching {byte_ratio:.1}×\n fewer weight bytes per token; kernel numbers: `cargo bench --bench packed_gemv`)"
    );
    Ok(())
}
