//! Quickstart: quantize one linear layer with stock GPTQ vs the paper's
//! two-stage method and print the layer-wise reconstruction losses.
//!
//! Run: `cargo run --release --example quickstart`

use tsgo::quant::{resolve_quantizer, QuantContext, QuantSpec};
use tsgo::tensor::Matrix;
use tsgo::util::rng::Rng;

fn main() -> tsgo::Result<()> {
    let mut rng = Rng::new(42);
    let (out_dim, in_dim) = (256, 256);

    // A weight matrix and a realistic (correlated, skewed) input Hessian.
    let w = Matrix::randn(out_dim, in_dim, 1.0, &mut rng);
    let t = 4 * in_dim;
    let mut x = Matrix::zeros(in_dim, t);
    for c in 0..t {
        let mut prev = 0.0f32;
        for r in 0..in_dim {
            let energy = if r % 9 == 0 { 4.0 } else { 0.5 };
            let v = 0.6 * prev + rng.normal() as f32 * energy;
            x[(r, c)] = v;
            prev = v;
        }
    }
    let mut h = x.matmul_bt(&x);
    h.scale_inplace(1.0 / t as f32);

    println!("quantizing a [{out_dim}x{in_dim}] layer, INT2, group=64\n");
    println!("{:<10} {:>14} {:>14} {:>10}", "method", "layer loss", "vs GPTQ", "time");
    let mut base = None;
    let ctx = QuantContext::default();
    for method in ["gptq", "stage1", "stage2", "ours"] {
        let quantizer = resolve_quantizer(method).expect("registered quantizer");
        let t0 = std::time::Instant::now();
        let res = quantizer.quantize(&w, &h, None, &QuantSpec::new(2, 64), &ctx)?;
        let dt = t0.elapsed();
        let rel = base.map(|b: f64| res.layer_loss / b).unwrap_or(1.0);
        if base.is_none() {
            base = Some(res.layer_loss);
        }
        println!(
            "{:<10} {:>14.4e} {:>13.1}% {:>10}",
            method,
            res.layer_loss,
            rel * 100.0,
            tsgo::util::fmt_duration(dt)
        );
    }
    println!("\nlower is better; 'ours' = stage1 + GPTQ + stage2 (Eq. 4, 5).");
    Ok(())
}
