//! Ablation sweep (the shape of Table 3, plus extras the paper mentions in
//! passing): every TwoStage ablation cell × bit width × group size on one model,
//! reporting summed layer-wise loss and stage-by-stage wall-clock.
//!
//! Run: `cargo run --release --example ablation_sweep`

use tsgo::calib::{calibration_batches, Corpus, CorpusKind};
use tsgo::model::{ModelWeights, Preset};
use tsgo::pipeline::{quantize_model, PipelineConfig};
use tsgo::quant::QuantSpec;
use tsgo::util::bench::Table;
use tsgo::util::rng::Rng;

fn main() -> tsgo::Result<()> {
    let preset = std::env::args()
        .nth(1)
        .and_then(|s| Preset::parse(&s))
        .unwrap_or(Preset::Tiny);
    let cfg = preset.config();
    println!(
        "ablation on preset '{}' ({:.2}M params)",
        preset.label(),
        cfg.n_params() as f64 / 1e6
    );

    let fp = match tsgo::model::store::load_model(std::path::Path::new("model.tsr")) {
        Ok(w) if w.config == cfg => w,
        _ => {
            let mut rng = Rng::new(3);
            ModelWeights::init(cfg, &mut rng)
        }
    };
    let corpus = Corpus::generate(CorpusKind::SynthWiki, 200_000, 1);
    let (train_split, _) = corpus.split(0.1);
    let calib = calibration_batches(train_split, 8, cfg.seq_len, 4, 3);

    let mut table = Table::new(&[
        "bits", "group", "stage1", "stage2", "layer loss", "Δ vs GPTQ", "time", "t_scales",
        "t_gptq", "t_stage2",
    ]);
    for bits in [2u8, 3] {
        for group in [64usize, 32] {
            let mut base = None;
            for (method, s1, s2) in [
                ("gptq", "", ""),
                ("stage1", "\u{2713}", ""),
                ("stage2", "", "\u{2713}"),
                ("ours", "\u{2713}", "\u{2713}"),
            ] {
                let spec = QuantSpec::new(bits, group);
                let (_, rep) =
                    quantize_model(&fp, &calib, &PipelineConfig::new(spec, method))?;
                let loss = rep.total_loss();
                let delta = match base {
                    None => {
                        base = Some(loss);
                        "—".to_string()
                    }
                    Some(b) => format!("{:+.1}%", (loss / b - 1.0) * 100.0),
                };
                table.row(vec![
                    format!("{bits}"),
                    format!("{group}"),
                    s1.into(),
                    s2.into(),
                    format!("{loss:.4e}"),
                    delta,
                    tsgo::util::fmt_duration(rep.total_time),
                    tsgo::util::fmt_duration(rep.time_scales),
                    tsgo::util::fmt_duration(rep.time_gptq),
                    tsgo::util::fmt_duration(rep.time_stage2),
                ]);
            }
        }
    }
    table.print("ablation (Table-3 shape; loss = Σ layer-wise reconstruction loss)");
    Ok(())
}
