# Repo-level entry points. `make check` is the tier-1 gate
# (build + tests + formatting).

.PHONY: check build test fmt clippy bench-json bench-check artifacts

check:
	bash ci.sh

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy -q -- -D warnings

# Run the packed-GEMV benchmark and drop its machine-readable baseline
# (tokens/s, GB/s, scalar-vs-SIMD speedup per bit width) at the repo root.
bench-json:
	cd rust && TSGO_BENCH_JSON=../BENCH_packed_gemv.json cargo bench --bench packed_gemv

# Regression guard: run the packed-GEMV bench into a scratch file and
# compare against the committed BENCH_packed_gemv.json baseline — fails on a
# >15% tokens/s drop per bit width (TSGO_BENCH_TOLERANCE overrides). The
# committed seed baseline carries provenance "seeded-unmeasured" and only
# reports; `make bench-json` + commit arms the hard gate.
bench-check:
	cd rust && TSGO_BENCH_JSON=../BENCH_packed_gemv.fresh.json cargo bench --bench packed_gemv
	cd rust && cargo run --release --quiet --bin bench_check -- ../BENCH_packed_gemv.json ../BENCH_packed_gemv.fresh.json

# AOT-lower the L2/L1 JAX + Pallas graphs to HLO artifacts for the runtime.
artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts
