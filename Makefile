# Repo-level entry points. `make check` is the tier-1 gate
# (build + tests + formatting).

.PHONY: check build test fmt clippy bench-json artifacts

check:
	bash ci.sh

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy -q -- -D warnings

# Run the packed-GEMV benchmark and drop its machine-readable baseline
# (tokens/s, GB/s, scalar-vs-SIMD speedup per bit width) at the repo root.
bench-json:
	cd rust && TSGO_BENCH_JSON=../BENCH_packed_gemv.json cargo bench --bench packed_gemv

# AOT-lower the L2/L1 JAX + Pallas graphs to HLO artifacts for the runtime.
artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts
