"""AOT lowering: JAX graphs (+ embedded Pallas kernels) → HLO **text**
artifacts + manifest.json for the rust runtime.

HLO text, NOT serialized protos: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published `xla`
crate binds) rejects; the text parser reassigns ids and round-trips cleanly.
All entries are lowered with ``return_tuple=True`` and unwrapped with
``to_tuple()`` on the rust side.

Usage: ``python -m compile.aot --out ../artifacts --preset small``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .kernels import dequant_matmul, hessian_accum, stage1_grid_losses

# Fixed AOT batch shapes (recorded in the manifest).
EVAL_BATCH = 1
TRAIN_BATCH = 8
HESSIAN_T = 2048  # token-chunk the hessian entry accepts per call
STAGE1_BETAS = 40
DEQ_T = 16  # decode-like small batch for the fused dequant matmul entry


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def lower_entry(fn, example_args):
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def build_entries(cfg, group_size, bits):
    """Yield (entry_name, hlo_text, inputs_spec, outputs_spec)."""
    order = M.param_order(cfg)
    n = len(order)
    param_specs = [spec(name, shape) for name, shape in order]
    param_shapes = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in order]

    # ---- forward_logits ----------------------------------------------------
    fwd, _ = M.make_forward(cfg, EVAL_BATCH)
    tokens = jax.ShapeDtypeStruct((EVAL_BATCH, cfg.seq_len), jnp.int32)
    yield (
        "forward_logits",
        lower_entry(fwd, param_shapes + [tokens]),
        param_specs + [spec("tokens", (EVAL_BATCH, cfg.seq_len), "i32")],
        [spec("logits", (EVAL_BATCH, cfg.seq_len, cfg.vocab))],
    )

    # ---- train_step ----------------------------------------------------------
    step_fn, _ = T.make_train_step(cfg)
    tt = jax.ShapeDtypeStruct((TRAIN_BATCH, cfg.seq_len), jnp.int32)
    mm = jax.ShapeDtypeStruct((TRAIN_BATCH, cfg.seq_len), jnp.float32)
    scal = jax.ShapeDtypeStruct((), jnp.int32)
    train_inputs = (
        param_shapes * 3 + [scal, tt, tt, mm]
    )
    yield (
        "train_step",
        lower_entry(step_fn, train_inputs),
        (
            param_specs
            + [spec("m." + nm, sh) for nm, sh in order]
            + [spec("v." + nm, sh) for nm, sh in order]
            + [
                spec("step", (), "i32"),
                spec("tokens", (TRAIN_BATCH, cfg.seq_len), "i32"),
                spec("targets", (TRAIN_BATCH, cfg.seq_len), "i32"),
                spec("mask", (TRAIN_BATCH, cfg.seq_len)),
            ]
        ),
        (
            [spec("loss", ())]
            + param_specs
            + [spec("m." + nm, sh) for nm, sh in order]
            + [spec("v." + nm, sh) for nm, sh in order]
        ),
    )

    # ---- hessian_accum (d_model and ffn variants) ---------------------------
    for tag, dim in (("d", cfg.d_model), ("ffn", cfg.ffn)):
        x = jax.ShapeDtypeStruct((HESSIAN_T, dim), jnp.float32)
        yield (
            f"hessian_accum_{tag}",
            lower_entry(lambda xx: (hessian_accum(xx),), [x]),
            [spec("x", (HESSIAN_T, dim))],
            [spec("h", (dim, dim))],
        )

    # ---- stage1_grid (for every linear input dim) ---------------------------
    # one entry per (rows, cols) linear shape in the model
    shapes = sorted(
        {(cfg.d_model, cfg.d_model), (cfg.ffn, cfg.d_model), (cfg.d_model, cfg.ffn)}
    )
    for (rows, cols) in shapes:
        n_g = cols // group_size
        w = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
        hb = jax.ShapeDtypeStruct((n_g, group_size, group_size), jnp.float32)
        betas = jax.ShapeDtypeStruct((STAGE1_BETAS,), jnp.float32)

        def s1(ww, hh, bb):
            return (stage1_grid_losses(ww, hh, bb, bits=bits),)

        yield (
            f"stage1_grid_{rows}x{cols}",
            lower_entry(s1, [w, hb, betas]),
            [
                spec("w", (rows, cols)),
                spec("h_blocks", (n_g, group_size, group_size)),
                spec("betas", (STAGE1_BETAS,)),
            ],
            [spec("losses", (n_g, STAGE1_BETAS, rows))],
        )

    # ---- fused dequant matmul (decode projection shape) ---------------------
    dq_bits = 4 if bits == 3 else bits  # 3-bit is stored padded to 4 for the kernel
    per = 32 // dq_bits
    rows, cols = cfg.d_model, cfg.d_model
    x = jax.ShapeDtypeStruct((DEQ_T, cols), jnp.float32)
    qw = jax.ShapeDtypeStruct((rows, cols // per), jnp.uint32)
    sc = jax.ShapeDtypeStruct((rows, cols // group_size), jnp.float32)

    def dq(xx, qq, ss, zz):
        return (
            dequant_matmul(xx, qq, ss, zz, bits=dq_bits, group_size=group_size),
        )

    yield (
        "dequant_matmul",
        lower_entry(dq, [x, qw, sc, sc]),
        [
            spec("x", (DEQ_T, cols)),
            spec("qwords", (rows, cols // per), "u32"),
            spec("scales", (rows, cols // group_size)),
            spec("zeros", (rows, cols // group_size)),
        ],
        [spec("y", (DEQ_T, rows))],
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(M.PRESETS))
    ap.add_argument("--group-size", type=int, default=64)
    ap.add_argument("--bits", type=int, default=2)
    args = ap.parse_args()

    cfg = M.PRESETS[args.preset]
    os.makedirs(args.out, exist_ok=True)
    entries = {}
    for name, hlo, inputs, outputs in build_entries(cfg, args.group_size, args.bits):
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(hlo)
        entries[name] = {"file": fname, "inputs": inputs, "outputs": outputs}
        print(f"  lowered {name:<28} -> {fname} ({len(hlo)/1e6:.2f} MB)")

    manifest = {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "ffn": cfg.ffn,
            "seq_len": cfg.seq_len,
        },
        "preset": args.preset,
        "group_size": args.group_size,
        "bits": args.bits,
        "train": {
            "batch": TRAIN_BATCH,
            "lr": T.LR,
            "beta1": T.BETA1,
            "beta2": T.BETA2,
            "weight_decay": T.WEIGHT_DECAY,
        },
        "entries": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} entries to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
