"""Pure-jnp oracles for every Pallas kernel — the build-time correctness
ground truth (`python/tests/test_kernels.py` asserts allclose against
these under hypothesis-driven shape/width sweeps)."""

import jax.numpy as jnp


def hessian_ref(x):
    """x: [T, d] → xᵀx / T."""
    t = x.shape[0]
    return (x.T @ x) / jnp.float32(t)


def minmax_scale_ref(w_group, qmax, beta):
    """Per-row (scale, zero) of a ``[out, g]`` group at clipping β.
    Mirrors rust `quant::scale::minmax_scale` exactly."""
    lo = jnp.minimum(jnp.min(w_group, axis=-1), 0.0) * beta
    hi = jnp.maximum(jnp.max(w_group, axis=-1), 0.0) * beta
    s = jnp.maximum((hi - lo) / qmax, 1e-10)
    z = jnp.clip(jnp.round(-lo / s), 0.0, qmax)
    return s, z


def stage1_losses_ref(w, h_blocks, betas, bits):
    """[n_g, M, out] losses, the oracle for `stage1_grid_losses`."""
    out, cin = w.shape
    n_g, g, _ = h_blocks.shape
    qmax = float(2**bits - 1)
    wg = w.reshape(out, n_g, g)
    losses = []
    for gi in range(n_g):
        row = []
        for beta in betas:
            s, z = minmax_scale_ref(wg[:, gi, :], qmax, beta)
            wint = jnp.clip(jnp.round(wg[:, gi, :] / s[:, None]) + z[:, None], 0.0, qmax)
            e = s[:, None] * (wint - z[:, None]) - wg[:, gi, :]
            row.append(jnp.einsum("og,gh,oh->o", e, h_blocks[gi], e))
        losses.append(jnp.stack(row))
    return jnp.stack(losses)


def dequant_ref(wint, scales, zeros, group_size):
    """Dequantize ``[out, in]`` integers with per-(row, group) params."""
    out, cin = wint.shape
    n_g = cin // group_size
    s = jnp.repeat(scales, group_size, axis=1)
    z = jnp.repeat(zeros, group_size, axis=1)
    return s * (wint.astype(jnp.float32) - z)


def dequant_matmul_ref(x, wint, scales, zeros, group_size):
    """y = x · dequant(wint)ᵀ — oracle for the fused kernel."""
    return x @ dequant_ref(wint, scales, zeros, group_size).T
