"""Fused unpack + dequantize + matmul kernel — the inference hot path that
makes weight-only group-wise quantization pay off (paper §2.2; what vLLM /
TensorRT-LLM kernels do for AWQ/GPTQ checkpoints).

Weights stay bit-packed ``uint32`` in HBM. Each grid step copies a packed
``[bo, bw]`` tile into VMEM, unpacks it with vector shift/mask ops on the
VPU, applies the per-(row, group) scales/zeros, and feeds the MXU with an
f32 ``[t, bi] × [bi, bo]`` matmul, accumulating over the input-dimension
grid axis. The CUDA original would do the unpack in registers per warp and
hit tensor cores; the BlockSpec index maps express the same HBM↔VMEM
schedule the threadblock tiling did.

Supported widths: 2/4/8 bits (32/bits values per word — no word straddling;
the paper's 3-bit format is stored zero-padded to 4 bits for this kernel,
matching how production kernels handle odd widths).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pack_weights(wint, bits):
    """Pack ``wint: [out, in]`` (uints < 2^bits) into uint32 words
    ``[out, in·bits/32]``, little-endian within each word. Pure jnp —
    build-time helper and the layout contract for the rust side."""
    out, cin = wint.shape
    per = 32 // bits
    assert cin % per == 0, (cin, per)
    vals = wint.astype(jnp.uint32).reshape(out, cin // per, per)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[None, None, :]
    return jnp.sum(vals << shifts, axis=2, dtype=jnp.uint32)


def _unpack(words, bits):
    """``[rows, nwords] uint32`` → ``[rows, nwords·per] f32`` values."""
    per = 32 // bits
    mask = jnp.uint32(2**bits - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[None, None, :]
    vals = (words[:, :, None] >> shifts) & mask
    return vals.reshape(words.shape[0], -1).astype(jnp.float32)


def _dq_kernel(x_ref, q_ref, s_ref, z_ref, o_ref, *, bits, group_size):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [t, bi]
    w = _unpack(q_ref[...], bits)  # [bo, bi]
    s = s_ref[...]  # [bo, n_g_blk]
    z = z_ref[...]
    reps = w.shape[1] // s.shape[1]  # = group_size / ... per block
    sfull = jnp.repeat(s, reps, axis=1)  # [bo, bi]
    zfull = jnp.repeat(z, reps, axis=1)
    wdq = sfull * (w - zfull)
    o_ref[...] += jnp.dot(x, wdq.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "block_out", "block_in"))
def dequant_matmul(x, qwords, scales, zeros, *, bits, group_size,
                   block_out=64, block_in=64):
    """``y = x · dequant(q)ᵀ``.

    x: [T, in] f32 ; qwords: [out, in·bits/32] uint32 ;
    scales/zeros: [out, in/group_size] f32 → y: [T, out].
    ``block_in`` must be a multiple of ``group_size`` (and of 32/bits).
    """
    t, cin = x.shape
    out, nwords = qwords.shape
    per = 32 // bits
    assert nwords * per == cin
    assert block_in % group_size == 0 and block_in % per == 0
    assert cin % block_in == 0 and out % block_out == 0
    grid = (out // block_out, cin // block_in)
    words_per_block = block_in // per
    groups_per_block = block_in // group_size
    kern = functools.partial(_dq_kernel, bits=bits, group_size=group_size)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, block_in), lambda o, k: (0, k)),
            pl.BlockSpec((block_out, words_per_block), lambda o, k: (o, k)),
            pl.BlockSpec((block_out, groups_per_block), lambda o, k: (o, k)),
            pl.BlockSpec((block_out, groups_per_block), lambda o, k: (o, k)),
        ],
        out_specs=pl.BlockSpec((t, block_out), lambda o, k: (0, o)),
        out_shape=jax.ShapeDtypeStruct((t, out), jnp.float32),
        interpret=True,
    )(x, qwords, scales, zeros)
