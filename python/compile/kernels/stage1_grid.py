"""Stage-1 grid-search kernel (paper Eq. 4).

For every (group ``i``, candidate ``β``) evaluate the input-aware loss

    L(r, i, β) = (s w_int − w_r,i)ᵀ H_ii (s w_int − w_r,i)

for all output rows ``r`` at once: the error matrix ``E: [out, g]`` hits the
``[g, g]`` Hessian block on the MXU and is reduced row-wise on-chip. The GPU
analog would assign a threadblock per (group, candidate); here each is one
grid step with the candidate axis innermost so the weight/Hessian tiles stay
resident in VMEM across the β sweep.

The argmin over β and the final (scale, zero) reconstruction are cheap and
stay in plain jnp (`stage1_scales`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _grid_kernel(w_ref, hii_ref, beta_ref, loss_ref, *, qmax):
    w = w_ref[0]  # [out, g]
    hii = hii_ref[0]  # [g, g]
    beta = beta_ref[0]

    lo = jnp.minimum(jnp.min(w, axis=1), 0.0) * beta  # [out]
    hi = jnp.maximum(jnp.max(w, axis=1), 0.0) * beta
    s = jnp.maximum((hi - lo) / qmax, 1e-10)  # [out]
    z = jnp.clip(jnp.round(-lo / s), 0.0, qmax)  # [out]
    wint = jnp.clip(jnp.round(w / s[:, None]) + z[:, None], 0.0, qmax)
    e = s[:, None] * (wint - z[:, None]) - w  # [out, g]
    eh = jnp.dot(e, hii, preferred_element_type=jnp.float32)  # MXU
    loss_ref[0, 0] = jnp.sum(eh * e, axis=1)  # [out]


@functools.partial(jax.jit, static_argnames=("bits",))
def stage1_grid_losses(w, h_blocks, betas, *, bits):
    """Losses for every (group, β, row).

    w: [out, in] (in = n_g · g) ; h_blocks: [n_g, g, g] ; betas: [M]
    → [n_g, M, out] f32.
    """
    out, cin = w.shape
    n_g, g, _ = h_blocks.shape
    assert cin == n_g * g, (cin, n_g, g)
    (m,) = betas.shape
    qmax = float(2**bits - 1)
    wg = w.reshape(out, n_g, g).transpose(1, 0, 2)  # [n_g, out, g]
    kern = functools.partial(_grid_kernel, qmax=qmax)
    return pl.pallas_call(
        kern,
        grid=(n_g, m),
        in_specs=[
            pl.BlockSpec((1, out, g), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, g, g), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, 1, out), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n_g, m, out), jnp.float32),
        interpret=True,
    )(wg, h_blocks, betas)


@functools.partial(jax.jit, static_argnames=("bits",))
def stage1_scales(w, h_blocks, betas, *, bits):
    """Full Stage-1: kernel losses → argmin over β → (scales, zeros).

    Returns ``scales, zeros: [out, n_g]``.
    """
    out, cin = w.shape
    n_g, g, _ = h_blocks.shape
    qmax = float(2**bits - 1)
    losses = stage1_grid_losses(w, h_blocks, betas, bits=bits)  # [n_g, M, out]
    best = jnp.argmin(losses, axis=1)  # [n_g, out]
    beta_star = betas[best].T  # [out, n_g]
    wg = w.reshape(out, n_g, g)
    lo = jnp.minimum(jnp.min(wg, axis=2), 0.0) * beta_star  # [out, n_g]
    hi = jnp.maximum(jnp.max(wg, axis=2), 0.0) * beta_star
    s = jnp.maximum((hi - lo) / qmax, 1e-10)
    z = jnp.clip(jnp.round(-lo / s), 0.0, qmax)
    return s, z
