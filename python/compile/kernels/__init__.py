"""L1 Pallas kernels (build-time only).

Every kernel runs under ``interpret=True`` so the lowered HLO contains plain
ops executable on any PJRT backend (the CPU plugin in this environment); real
TPU lowering would emit Mosaic custom-calls instead. Kernels are structured
for the TPU memory model regardless — see DESIGN.md §7 Hardware-Adaptation.
"""

from .hessian import hessian_accum
from .stage1_grid import stage1_grid_losses, stage1_scales
from .dequant_matmul import dequant_matmul, pack_weights

__all__ = [
    "hessian_accum",
    "stage1_grid_losses",
    "stage1_scales",
    "dequant_matmul",
    "pack_weights",
]
