"""Hessian accumulation kernel: ``H = Xᵀ X / T`` for ``X: [T, d]``.

The calibration hot loop of the GPTQ pipeline (Eq. 1: ``H = E[X Xᵀ]`` with X
laid out ``[in, T]``; we take the transposed layout the capture pass
produces). TPU mapping: grid over ``(I, J, K)`` — ``(I, J)`` tile the output
Hessian, ``K`` walks token chunks accumulating into the same output block
(`o_ref` is revisited across the K axis, the canonical MXU reduction
pattern). VMEM per step = two ``[tk, b]`` input tiles + one ``[b, b]``
accumulator tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hessian_kernel(xi_ref, xj_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = xi_ref[...]  # [tk, bi]
    xj = xj_ref[...]  # [tk, bj]
    o_ref[...] += jnp.dot(xi.T, xj, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block", "t_chunk"))
def hessian_accum(x, *, block=64, t_chunk=128):
    """``x: [T, d]`` → ``H = xᵀx / T : [d, d]`` (f32).

    ``d`` must be a multiple of ``block`` and ``T`` of ``t_chunk``
    (the AOT entry pads the token axis; zeros contribute nothing).
    """
    t, d = x.shape
    assert d % block == 0, f"d={d} not a multiple of block={block}"
    assert t % t_chunk == 0, f"T={t} not a multiple of t_chunk={t_chunk}"
    grid = (d // block, d // block, t // t_chunk)
    h = pl.pallas_call(
        _hessian_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_chunk, block), lambda i, j, k: (k, i)),
            pl.BlockSpec((t_chunk, block), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(x, x)
    return h / jnp.float32(t)
