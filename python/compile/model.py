"""L2: the Llamette transformer in JAX — the canonical model definition.

Numerics contract with the rust mirror (`rust/src/model/forward.rs`):
RMSNorm ε = 1e-5; RoPE rotates pairs ``(x[2i], x[2i+1])`` within each head at
angle ``pos · 10000^(−2i/head_dim)``; pre-norm residual blocks; SwiGLU MLP;
untied head. Parameters travel as a flat list in ``param_order()`` — the
same order `ModelWeights::flat_params` produces on the rust side.

Everything here is lowered once by `aot.py`; nothing imports this at
runtime.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

RMS_EPS = 1e-5
ROPE_BASE = 10_000.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    ffn: int
    seq_len: int

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


PRESETS = {
    "tiny": ModelConfig(256, 64, 2, 2, 128, 64),
    "small": ModelConfig(256, 256, 4, 4, 704, 128),
    "base": ModelConfig(256, 512, 6, 8, 1408, 128),
}


def param_order(cfg):
    """[(name, shape)] in the canonical flat order shared with rust."""
    d, f, v = cfg.d_model, cfg.ffn, cfg.vocab
    out = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        out += [
            (f"layers.{i}.ln1", (d,)),
            (f"layers.{i}.wq", (d, d)),
            (f"layers.{i}.wk", (d, d)),
            (f"layers.{i}.wv", (d, d)),
            (f"layers.{i}.wo", (d, d)),
            (f"layers.{i}.ln2", (d,)),
            (f"layers.{i}.w1", (f, d)),
            (f"layers.{i}.w3", (f, d)),
            (f"layers.{i}.w2", (d, f)),
        ]
    out += [("ln_f", (d,)), ("head", (v, d))]
    return out


def unflatten(cfg, flat):
    """Flat param list → structured dict."""
    names = [n for n, _ in param_order(cfg)]
    assert len(flat) == len(names), (len(flat), len(names))
    return dict(zip(names, flat))


def init_params(cfg, key):
    """Random init mirroring rust `ModelWeights::init` (shapes/std only —
    bit-exact equality is not required; checkpoints carry the weights)."""
    params = []
    std = 0.02
    resid_std = std / (2.0 * cfg.n_layers) ** 0.5
    for name, shape in param_order(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("wo", "w2")):
            params.append(jax.random.normal(sub, shape, jnp.float32) * resid_std)
        else:
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def rmsnorm(x, gain):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + RMS_EPS) * gain


def rope(x, n_heads, pos0=0):
    """x: [T, d] → rotated. Pairs (2i, 2i+1) within each head."""
    t, d = x.shape
    hd = d // n_heads
    xh = x.reshape(t, n_heads, hd // 2, 2)
    pos = jnp.arange(t, dtype=jnp.float32)[:, None] + pos0
    inv = ROPE_BASE ** (-2.0 * jnp.arange(hd // 2, dtype=jnp.float32) / hd)
    theta = pos * inv[None, :]  # [T, hd/2]
    sin, cos = jnp.sin(theta), jnp.cos(theta)
    a, b = xh[..., 0], xh[..., 1]  # [T, H, hd/2]
    ra = a * cos[:, None, :] - b * sin[:, None, :]
    rb = a * sin[:, None, :] + b * cos[:, None, :]
    return jnp.stack([ra, rb], axis=-1).reshape(t, d)


def attention(q, k, v, n_heads):
    """Causal MHA over [T, d] (single sequence)."""
    t, d = q.shape
    hd = d // n_heads
    qh = q.reshape(t, n_heads, hd).transpose(1, 0, 2)  # [H, T, hd]
    kh = k.reshape(t, n_heads, hd).transpose(1, 0, 2)
    vh = v.reshape(t, n_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", qh, kh) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,hkd->hqd", probs, vh)  # [H, T, hd]
    return ctx.transpose(1, 0, 2).reshape(t, d)


def block(p, i, h, n_heads):
    ln1 = p[f"layers.{i}.ln1"]
    x = rmsnorm(h, ln1)
    q = rope(x @ p[f"layers.{i}.wq"].T, n_heads)
    k = rope(x @ p[f"layers.{i}.wk"].T, n_heads)
    v = x @ p[f"layers.{i}.wv"].T
    ctx = attention(q, k, v, n_heads)
    h = h + ctx @ p[f"layers.{i}.wo"].T
    x = rmsnorm(h, p[f"layers.{i}.ln2"])
    act = jax.nn.silu(x @ p[f"layers.{i}.w1"].T) * (x @ p[f"layers.{i}.w3"].T)
    return h + act @ p[f"layers.{i}.w2"].T


def forward_one(cfg, p, tokens):
    """tokens: [S] int32 → logits [S, vocab]."""
    h = p["embed"][tokens]
    for i in range(cfg.n_layers):
        h = block(p, i, h, cfg.n_heads)
    return rmsnorm(h, p["ln_f"]) @ p["head"].T


def forward_logits(cfg, flat_params, tokens):
    """tokens: [B, S] → logits [B, S, vocab] (vmapped over the batch)."""
    p = unflatten(cfg, flat_params)
    return jax.vmap(lambda t: forward_one(cfg, p, t))(tokens)


def loss_fn(cfg, flat_params, tokens, targets, mask):
    """Mean masked next-token cross-entropy.

    tokens/targets/mask: [B, S] (targets already shifted; mask f32).
    """
    logits = forward_logits(cfg, flat_params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_forward(cfg, batch):
    """Jit-able ``f(*params, tokens)`` for AOT lowering."""
    n = len(param_order(cfg))

    def f(*args):
        flat, tokens = list(args[:n]), args[n]
        return (forward_logits(cfg, flat, tokens),)

    return f, n


@functools.partial(jax.jit, static_argnames=("cfg",))
def jit_forward(cfg, flat_params, tokens):
    return forward_logits(cfg, flat_params, tokens)
