"""L2: fused AdamW training step, AOT-lowered for the rust training loop.

One artifact executes: forward + backward + AdamW update, returning the new
parameters, new optimizer moments and the scalar loss. The rust side owns
the data pipeline and the step loop; XLA owns the math. Buffer donation is
requested for params/moments so XLA can update in place.
"""

import jax
import jax.numpy as jnp

from . import model as M

# AdamW hyper-parameters (baked into the artifact; recorded in the manifest).
LR = 3e-4
BETA1, BETA2 = 0.9, 0.95
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.01
GRAD_CLIP = 1.0


def _is_decayed(name):
    # decay matrices only (norm gains and biases are not decayed)
    return not (name.endswith(("ln1", "ln2")) or name == "ln_f")


def make_train_step(cfg):
    """Returns ``(step_fn, n_params)``.

    step_fn(*params, *m, *v, step, tokens, targets, mask)
        → (loss, *new_params, *new_m, *new_v)
    """
    order = M.param_order(cfg)
    n = len(order)
    names = [name for name, _ in order]

    def step_fn(*args):
        params = list(args[:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        step = args[3 * n]  # i32 scalar, 1-based
        tokens, targets, mask = args[3 * n + 1], args[3 * n + 2], args[3 * n + 3]

        loss, grads = jax.value_and_grad(
            lambda ps: M.loss_fn(cfg, ps, tokens, targets, mask)
        )(params)

        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
        clip = jnp.minimum(1.0, GRAD_CLIP / (gnorm + 1e-12))
        grads = [g * clip for g in grads]

        t = step.astype(jnp.float32)
        bc1 = 1.0 - BETA1**t
        bc2 = 1.0 - BETA2**t
        new_p, new_m, new_v = [], [], []
        for name, p, mi, vi, g in zip(names, params, m, v, grads):
            mi = BETA1 * mi + (1.0 - BETA1) * g
            vi = BETA2 * vi + (1.0 - BETA2) * g * g
            mhat = mi / bc1
            vhat = vi / bc2
            upd = mhat / (jnp.sqrt(vhat) + ADAM_EPS)
            if _is_decayed(name):
                upd = upd + WEIGHT_DECAY * p
            new_p.append(p - LR * upd)
            new_m.append(mi)
            new_v.append(vi)
        return tuple([loss] + new_p + new_m + new_v)

    return step_fn, n
