"""L2 model tests: shapes, causality, loss sanity, train-step behaviour."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model as M
from compile import train as T


CFG = M.PRESETS["tiny"]


def params(seed=0):
    return M.init_params(CFG, jax.random.PRNGKey(seed))


def test_param_order_matches_counts():
    order = M.param_order(CFG)
    assert len(order) == 2 + 9 * CFG.n_layers + 1
    total = sum(int(np.prod(s)) for _, s in order)
    # mirror of rust ModelConfig::n_params
    d, f, v, L = CFG.d_model, CFG.ffn, CFG.vocab, CFG.n_layers
    expect = v * d * 2 + L * (4 * d * d + 3 * d * f + 2 * d) + d
    assert total == expect


def test_forward_shapes():
    ps = params()
    tokens = jnp.arange(2 * CFG.seq_len, dtype=jnp.int32).reshape(2, CFG.seq_len) % 256
    logits = M.forward_logits(CFG, ps, tokens)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_causality():
    ps = params(1)
    t = CFG.seq_len
    a = jnp.zeros((1, t), jnp.int32).at[0, :].set(5)
    b = a.at[0, t - 1].set(99)
    la = M.forward_logits(CFG, ps, a)
    lb = M.forward_logits(CFG, ps, b)
    np.testing.assert_allclose(
        np.asarray(la[0, : t - 1]), np.asarray(lb[0, : t - 1]), atol=1e-5
    )


def test_rope_position_dependence():
    ps = params(2)
    tokens = jnp.full((1, 8), 42, jnp.int32)
    logits = M.forward_logits(CFG, ps, tokens)
    assert not np.allclose(np.asarray(logits[0, 1]), np.asarray(logits[0, 5]))


def test_loss_uniform_at_init():
    ps = params(3)
    tokens = (jnp.arange(CFG.seq_len, dtype=jnp.int32) * 37 % 251)[None]
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    loss = M.loss_fn(CFG, ps, tokens, targets, mask)
    assert abs(float(loss) - np.log(256)) < 0.4


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 1000))
def test_train_step_decreases_loss_on_repeated_batch(seed):
    step_fn, n = T.make_train_step(CFG)
    ps = params(seed)
    m = [jnp.zeros_like(p) for p in ps]
    v = [jnp.zeros_like(p) for p in ps]
    r = np.random.default_rng(seed)
    tokens = jnp.asarray(
        r.integers(0, 256, size=(T and 8, CFG.seq_len)), dtype=jnp.int32
    )
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    jit_step = jax.jit(step_fn)
    losses = []
    for i in range(1, 6):
        out = jit_step(*ps, *m, *v, jnp.int32(i), tokens, targets, mask)
        loss, rest = out[0], out[1:]
        ps = list(rest[:n])
        m = list(rest[n : 2 * n])
        v = list(rest[2 * n : 3 * n])
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_rope_pure_rotation_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (6, CFG.d_model))
    y = M.rope(x, CFG.n_heads)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=1),
        np.linalg.norm(np.asarray(y), axis=1),
        rtol=1e-5,
    )
    # position 0 is the identity
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(x[0]), atol=1e-6)
