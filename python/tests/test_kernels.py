"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, bit widths and group sizes; every kernel must
match its `ref.py` oracle to float32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import (
    dequant_matmul,
    hessian_accum,
    pack_weights,
    stage1_grid_losses,
    stage1_scales,
)
from compile.kernels import ref


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- hessian --
@settings(max_examples=12, deadline=None)
@given(
    t_chunks=st.integers(1, 3),
    blocks=st.integers(1, 3),
    seed=st.integers(0, 2**31),
)
def test_hessian_matches_ref(t_chunks, blocks, seed):
    t, d = 128 * t_chunks, 64 * blocks
    x = rng(seed).normal(size=(t, d)).astype(np.float32)
    got = hessian_accum(jnp.asarray(x))
    want = ref.hessian_ref(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_hessian_is_symmetric_psd():
    x = rng(0).normal(size=(256, 64)).astype(np.float32)
    h = np.asarray(hessian_accum(jnp.asarray(x)))
    np.testing.assert_allclose(h, h.T, atol=1e-5)
    evals = np.linalg.eigvalsh(h.astype(np.float64))
    assert evals.min() > -1e-4


def test_hessian_rejects_misaligned():
    with pytest.raises(AssertionError):
        hessian_accum(jnp.zeros((100, 64), jnp.float32))  # T not /128
    with pytest.raises(AssertionError):
        hessian_accum(jnp.zeros((128, 60), jnp.float32))  # d not /64


# ----------------------------------------------------------------- stage1 --
@settings(max_examples=10, deadline=None)
@given(
    out=st.sampled_from([8, 32]),
    n_g=st.integers(1, 3),
    g=st.sampled_from([16, 32, 64]),
    bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31),
)
def test_stage1_losses_match_ref(out, n_g, g, bits, seed):
    r = rng(seed)
    w = r.normal(size=(out, n_g * g)).astype(np.float32)
    xs = r.normal(size=(n_g, g, 4 * g)).astype(np.float32)
    hb = np.einsum("ngt,nht->ngh", xs, xs).astype(np.float32) / (4 * g)
    betas = np.linspace(0.4, 1.0, 7).astype(np.float32)
    got = stage1_grid_losses(jnp.asarray(w), jnp.asarray(hb), jnp.asarray(betas), bits=bits)
    want = ref.stage1_losses_ref(jnp.asarray(w), jnp.asarray(hb), jnp.asarray(betas), bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-4)


def test_stage1_scales_pick_argmin():
    r = rng(7)
    out, n_g, g, bits = 16, 2, 32, 2
    w = r.normal(size=(out, n_g * g)).astype(np.float32)
    xs = r.normal(size=(n_g, g, 128)).astype(np.float32)
    hb = np.einsum("ngt,nht->ngh", xs, xs).astype(np.float32) / 128
    betas = np.linspace(0.35, 1.0, 9).astype(np.float32)
    s, z = stage1_scales(jnp.asarray(w), jnp.asarray(hb), jnp.asarray(betas), bits=bits)
    losses = np.asarray(
        ref.stage1_losses_ref(jnp.asarray(w), jnp.asarray(hb), jnp.asarray(betas), bits)
    )  # [n_g, M, out]
    best = losses.argmin(axis=1)  # [n_g, out]
    qmax = 2.0**bits - 1
    wg = w.reshape(out, n_g, g)
    for gi in range(n_g):
        for row in range(out):
            beta = betas[best[gi, row]]
            lo = min(wg[row, gi].min(), 0.0) * beta
            hi = max(wg[row, gi].max(), 0.0) * beta
            s_want = max((hi - lo) / qmax, 1e-10)
            assert np.isclose(float(s[row, gi]), s_want, rtol=1e-5), (row, gi)
            assert 0.0 <= float(z[row, gi]) <= qmax


def test_stage1_identity_hessian_equals_l2_choice():
    # With H_ii = I the kernel's pick must equal the plain L2 grid pick.
    r = rng(3)
    out, g, bits = 8, 32, 2
    w = r.normal(size=(out, g)).astype(np.float32)
    hb = np.eye(g, dtype=np.float32)[None]
    betas = np.linspace(0.35, 1.0, 16).astype(np.float32)
    losses = np.asarray(
        stage1_grid_losses(jnp.asarray(w), jnp.asarray(hb), jnp.asarray(betas), bits=bits)
    )[0]  # [M, out]
    # manual L2 losses
    qmax = 2.0**bits - 1
    for mi, beta in enumerate(betas):
        lo = np.minimum(w.min(axis=1), 0.0) * beta
        hi = np.maximum(w.max(axis=1), 0.0) * beta
        s = np.maximum((hi - lo) / qmax, 1e-10)
        z = np.clip(np.round(-lo / s), 0, qmax)
        wint = np.clip(np.round(w / s[:, None]) + z[:, None], 0, qmax)
        e = s[:, None] * (wint - z[:, None]) - w
        np.testing.assert_allclose(losses[mi], (e * e).sum(axis=1), rtol=2e-3, atol=2e-5)


# --------------------------------------------------------- dequant matmul --
@settings(max_examples=10, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    out_blocks=st.integers(1, 2),
    in_blocks=st.integers(1, 2),
    group_size=st.sampled_from([32, 64]),
    t=st.sampled_from([1, 8, 16]),
    seed=st.integers(0, 2**31),
)
def test_dequant_matmul_matches_ref(bits, out_blocks, in_blocks, group_size, t, seed):
    r = rng(seed)
    out, cin = 64 * out_blocks, 64 * in_blocks
    wint = r.integers(0, 2**bits, size=(out, cin)).astype(np.uint32)
    scales = (r.random(size=(out, cin // group_size)) * 0.1 + 0.01).astype(np.float32)
    zeros = r.integers(0, 2**bits, size=(out, cin // group_size)).astype(np.float32)
    x = r.normal(size=(t, cin)).astype(np.float32)
    qwords = pack_weights(jnp.asarray(wint), bits)
    got = dequant_matmul(
        jnp.asarray(x), qwords, jnp.asarray(scales), jnp.asarray(zeros),
        bits=bits, group_size=group_size,
    )
    want = ref.dequant_matmul_ref(
        jnp.asarray(x), jnp.asarray(wint), jnp.asarray(scales), jnp.asarray(zeros),
        group_size,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_pack_layout_contract():
    # The packed u32 layout must match rust PackedInts (little-endian bit
    # order within a word): value k at column c lands at bits (c%per)*bits.
    bits = 4
    wint = jnp.asarray(np.arange(8, dtype=np.uint32)[None])  # [1, 8]
    words = np.asarray(pack_weights(wint, bits))
    assert words.shape == (1, 1)
    w = int(words[0, 0])
    for c in range(8):
        assert (w >> (c * 4)) & 0xF == c


@settings(max_examples=8, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31))
def test_pack_roundtrip(bits, seed):
    r = rng(seed)
    wint = r.integers(0, 2**bits, size=(4, 64)).astype(np.uint32)
    words = pack_weights(jnp.asarray(wint), bits)
    per = 32 // bits
    mask = 2**bits - 1
    back = np.zeros_like(wint)
    wn = np.asarray(words)
    for c in range(64):
        back[:, c] = (wn[:, c // per] >> ((c % per) * bits)) & mask
    np.testing.assert_array_equal(back, wint)
