"""AOT path tests: HLO-text lowering contract and manifest structure.

Full-preset lowering is exercised by `make artifacts` + the rust parity
tests; here we check the pieces cheaply (tiny shapes only).
"""

import json

import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M


def test_to_hlo_text_is_parseable_hlo():
    lowered = jax.jit(lambda x: (x @ x.T + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # return_tuple=True → root is a tuple
    assert "tuple" in text.lower()


def test_spec_helper():
    s = aot.spec("tokens", (1, 64), "i32")
    assert s == {"name": "tokens", "shape": [1, 64], "dtype": "i32"}


def test_build_entries_cover_required_set():
    cfg = M.PRESETS["tiny"]
    names = []
    for name, hlo, inputs, outputs in aot.build_entries(cfg, 32, 2):
        names.append(name)
        assert isinstance(hlo, str) and len(hlo) > 100, name
        assert inputs and outputs, name
        # shapes are JSON-serializable
        json.dumps({"inputs": inputs, "outputs": outputs})
        if name == "forward_logits":
            assert outputs[0]["shape"] == [aot.EVAL_BATCH, cfg.seq_len, cfg.vocab]
        if name == "train_step":
            n = len(M.param_order(cfg))
            assert len(inputs) == 3 * n + 4
            assert len(outputs) == 3 * n + 1
    assert "forward_logits" in names
    assert "train_step" in names
    assert any(n.startswith("hessian_accum") for n in names)
    assert any(n.startswith("stage1_grid") for n in names)
    assert "dequant_matmul" in names


def test_param_order_matches_rust_manifest_convention():
    cfg = M.PRESETS["small"]
    order = M.param_order(cfg)
    assert order[0][0] == "embed"
    assert order[-1][0] == "head"
    assert order[1][0] == "layers.0.ln1"
    # 9 tensors per layer between embed and ln_f
    assert len(order) == 2 + 9 * cfg.n_layers + 1
