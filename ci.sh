#!/usr/bin/env bash
# Tier-1 verification: build, tests (under BOTH kernel tables), formatting,
# bench compile, lints — the command `make check` runs and CI runs
# (.github/workflows/ci.yml). Requires a Rust toolchain (rustup.rs) and the
# crates.io deps in rust/Cargo.toml; see CHANGES.md for the current
# pass-set triage when no toolchain is available.
set -euo pipefail
cd "$(dirname "$0")/rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found — install a Rust toolchain (https://rustup.rs)" >&2
    exit 1
fi

cargo build --release

# The test suite must pass under BOTH kernel tables: the runtime-dispatched
# one (SIMD where the CPU supports it) and the forced-scalar portable one.
# They are bit-identical by construction — a failure in exactly one table
# means that invariant broke, so fail fast and say which table it was.
if ! cargo test -q; then
    echo "" >&2
    echo "FAILED: test suite under the DISPATCHED kernel table" >&2
    echo "        (runtime-selected SIMD/scalar — the default execution path)." >&2
    exit 1
fi
if ! TSGO_FORCE_SCALAR=1 cargo test -q; then
    echo "" >&2
    echo "FAILED: test suite under the FORCED-SCALAR kernel table (TSGO_FORCE_SCALAR=1)." >&2
    echo "        The dispatched run above passed: the scalar/SIMD bit-identity" >&2
    echo "        invariant (ROADMAP.md 'Kernel dispatch') is broken." >&2
    exit 1
fi
# Chaos pass: the whole suite with a deterministic fault armed via the
# fault-injection plane (util::fault): the 3rd step-job evaluation after
# each arming sleeps 20 ms. A sleep perturbs only timing — every token-
# identity assertion must still hold, and no serve path may wedge on it.
if ! TSGO_FAULT="step_worker_slow_ms=20@hit=3" cargo test -q; then
    echo "" >&2
    echo "FAILED: test suite with the fault plane armed (TSGO_FAULT=step_worker_slow_ms=20@hit=3)." >&2
    echo "        Both unarmed runs above passed: a 20 ms injected delay in one" >&2
    echo "        decode step-job changed behaviour — a timing assumption in the" >&2
    echo "        serving stack is load-bearing (ROADMAP.md 'Fault tolerance')." >&2
    exit 1
fi

cargo fmt --check
# All bench targets must keep compiling (they are plain main() binaries and
# easy to break silently since nothing else links them).
cargo bench --no-run
# Lint gate: warnings are errors. `|| true` is NOT acceptable here — a
# clippy regression must fail CI.
cargo clippy -q -- -D warnings
# Docs gate: rustdoc warnings (broken intra-doc links, malformed code
# fences) are errors — README/docs/ point into the API docs, so a silent
# rustdoc rot breaks the front door. Mirrored by the `docs` CI job.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
