#!/usr/bin/env bash
# Tier-1 verification: build, tests, formatting — the command `make check`
# runs and CI should run. Requires a Rust toolchain (rustup.rs) and the
# crates.io deps in rust/Cargo.toml; see CHANGES.md for the current
# pass-set triage when no toolchain is available.
set -euo pipefail
cd "$(dirname "$0")/rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found — install a Rust toolchain (https://rustup.rs)" >&2
    exit 1
fi

cargo build --release
cargo test -q
cargo fmt --check
# All bench targets must keep compiling (they are plain main() binaries and
# easy to break silently since nothing else links them).
cargo bench --no-run
# Lint gate: warnings are errors. `|| true` is NOT acceptable here — a
# clippy regression must fail CI.
cargo clippy -q -- -D warnings
